"""Iterative delta checkpointing: registry delta manifests, node layer
caches, and the ms2m_precopy migration strategy."""
import numpy as np

from repro.checkpoint import Registry
from repro.core import HashConsumer, run_migration_experiment


# ---------------------------------------------------------------------------
# registry layer
# ---------------------------------------------------------------------------

def test_delta_push_writes_strictly_fewer_bytes(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=64 * 1024)
    base = {
        "w": np.arange(1_000_000, dtype=np.float32),   # ~4MB, 61 chunks
        "kv": np.zeros(100_000, dtype=np.float32),
    }
    full = reg.push_image({"state": base})
    assert full.parent_id is None
    assert full.delta_bytes == full.total_bytes

    mutated = {"w": base["w"], "kv": base["kv"].copy()}
    mutated["kv"][:64] = 1.0  # dirty a slice -> a handful of chunks
    delta = reg.push_delta({"state": mutated}, full.image_id)
    assert delta.parent_id == full.image_id
    assert 0 < delta.written_bytes < full.written_bytes
    assert 0 < delta.delta_bytes < full.total_bytes
    # the dirty set is one chunk of kv (plus boundary effects), not ~4MB
    assert delta.delta_bytes <= 3 * 64 * 1024


def test_delta_image_roundtrip_and_parent_chain(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=32 * 1024)
    t0 = {"a": np.arange(50_000, dtype=np.int32)}
    t1 = {"a": t0["a"].copy()}
    t1["a"][123] = -7
    t2 = {"a": t1["a"].copy()}
    t2["a"][456] = -8

    p0 = reg.push_image({"state": t0})
    p1 = reg.push_delta({"state": t1}, p0.image_id)
    p2 = reg.push_delta({"state": t2}, p1.image_id)

    # a delta image is self-contained: pulling it needs no parent walk
    trees, _ = reg.pull_image(p2.image_id)
    np.testing.assert_array_equal(trees["state"]["a"], t2["a"])
    # forensic lineage is recorded
    assert reg.delta_chain(p2.image_id) == [p2.image_id, p1.image_id,
                                            p0.image_id]
    assert reg.image_parent(p0.image_id) is None


def test_pull_with_have_chunks_discounts_cached_chunks(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=16 * 1024)
    tree = {"a": np.arange(40_000, dtype=np.float32)}
    push = reg.push_image({"state": tree})

    _, cold = reg.pull_image(push.image_id, have_chunks=set())
    have = set(reg.image_chunks(push.image_id))
    _, warm = reg.pull_image(push.image_id, have_chunks=have)
    assert cold > 0
    assert warm == 0
    # chunk-size bookkeeping is consistent with the cold pull
    assert cold == sum(reg.image_chunks(push.image_id).values())


def test_node_prefetch_makes_restore_pull_free(tmp_path):
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    sim, api = cluster.sim, cluster.api
    worker = HashConsumer()
    push = cluster.registry.push_image({"state": worker.state_tree()})

    def flow():
        yield from api.prefetch_image("node1", push.image_id)
        restored = HashConsumer()
        yield from api.pull_and_restore(push.image_id, restored,
                                        node_name="node1")
        return restored

    done = sim.process(flow())
    sim.run()
    restored = done.value
    assert restored.state_equal(worker)
    events = {kind: kw for _, kind, kw in api.events}
    assert events["image_prefetched"]["bytes"] > 0
    assert events["restored"]["pulled"] == 0  # layer cache hit


# ---------------------------------------------------------------------------
# migration layer: the pre-copy loop
# ---------------------------------------------------------------------------

class StaticBulkConsumer(HashConsumer):
    """HashConsumer plus a large static 'weights' tree: the realistic image
    profile where delta rounds dirty only a tiny fraction of the state."""

    def __init__(self):
        super().__init__()
        self.weights = np.arange(1 << 18, dtype=np.float32)  # ~1 MiB static

    def state_tree(self):
        tree = super().state_tree()
        tree["weights"] = self.weights
        return tree


def test_precopy_migration_verified_with_converging_deltas(tmp_path):
    r = run_migration_experiment(
        "ms2m_precopy", 10.0, registry_root=str(tmp_path / "reg"),
        seed=4, worker_factory=StaticBulkConsumer, chunk_bytes=64 * 1024)
    assert r.verified
    rep = r.report
    assert rep.strategy == "ms2m_precopy"
    assert rep.precopy_rounds >= 1
    assert len(rep.precopy_round_bytes) == rep.precopy_rounds + 1
    # every delta round ships a small fraction of the full image
    assert all(b < 0.2 * rep.precopy_round_bytes[0]
               for b in rep.precopy_round_bytes[1:])
    # and the replay log left after the final round is one round's traffic,
    # not the whole transfer: the final marker must be past round 0's
    assert rep.precopy_round_dirty[-1] < sum(rep.precopy_round_dirty)


def test_precopy_optin_shrinks_statefulset_downtime(tmp_path):
    plain = run_migration_experiment(
        "ms2m_statefulset", 14.0, registry_root=str(tmp_path / "a"), seed=5)
    pre = run_migration_experiment(
        "ms2m_statefulset", 14.0, registry_root=str(tmp_path / "b"), seed=5,
        precopy=True)
    assert plain.verified and pre.verified
    assert pre.report.precopy_rounds >= 1
    # Fig. 4 downtime includes the replay of everything after the (single)
    # checkpoint; pre-copy moves the marker to the last round, so the
    # bounded replay — and with it the downtime — shrinks.
    assert pre.report.replayed_messages < plain.report.replayed_messages
    assert pre.downtime < plain.downtime


def test_precopy_stops_when_source_pauses(tmp_path):
    """If the source stops mid-loop (cutoff fired), the dirty set hits zero
    and the loop must exit instead of spinning to max_rounds."""
    r = run_migration_experiment(
        "ms2m_cutoff", 18.0, registry_root=str(tmp_path / "reg"), seed=1,
        t_replay_max=10.0, precopy=True,
        manager_kwargs={"precopy_max_rounds": 50})
    assert r.verified
    assert r.report.cutoff_fired
    assert r.report.precopy_rounds < 50
