"""Fault-injection subsystem and crash-consistent rollback/retry.

Covers the FaultSchedule grammar and seeded generation, each fault kind's
cluster-level effect, the MigrationContext.rollback guarantee (source
serving, mirror torn down, registry garbage collected) and the
orchestrator's retry loop (re-placement with failed targets excluded).
"""
import json
import tempfile

import pytest

from repro.cluster import Cluster, Fault, FaultSchedule, parse_fault
from repro.cluster.sim import TransferAborted
from repro.core import (
    HashConsumer,
    MigrationError,
    MigrationManager,
    MigrationPolicy,
    run_fleet_experiment,
    run_migration_experiment,
)


# ---------------------------------------------------------------------------
# Schedule grammar / generation
# ---------------------------------------------------------------------------

def test_parse_fault_grammar():
    f = parse_fault("node_flap@12,node=node1,duration=5")
    assert (f.kind, f.at, f.node, f.duration) == ("node_flap", 12.0,
                                                  "node1", 5.0)
    f = parse_fault("registry_outage@precopy_round:1,duration=8")
    assert f.at is None and f.phase == "precopy_round:1" and f.duration == 8.0
    f = parse_fault("registry_outage@phase:checkpoint,duration=2,after=1.5")
    assert f.phase == "checkpoint" and f.after == 1.5
    f = parse_fault("link_degrade@20,node=node0,duration=10,factor=0.1")
    assert f.factor == 0.1
    f = parse_fault("broker_stall@15,queue=orders,duration=4")
    assert f.queue == "orders"


@pytest.mark.parametrize("bad", [
    "no_at_sign",
    "unknown_kind@5",
    "node_crash@5",                      # node kinds need node=
    "node_flap@5,node=n0",               # flap needs duration
    "link_degrade@5,node=n0,duration=3,factor=1.5",  # factor in (0,1)
    "node_crash@5,node=n0,bogus=1",      # unknown key
    "node_crash@5,node=n0,phase=checkpoint",  # at AND phase
    "registry_outage@precopy_round:two,duration=3",  # round not an int
])
def test_parse_fault_rejects(bad):
    with pytest.raises(ValueError):
        parse_fault(bad)


def test_random_schedule_is_seed_deterministic():
    kw = dict(n_faults=5, t_window=(5.0, 50.0), nodes=("node1", "node2"),
              queues=("orders",))
    a = FaultSchedule.random(7, **kw)
    b = FaultSchedule.random(7, **kw)
    c = FaultSchedule.random(8, **kw)
    assert a.rows() == b.rows()
    assert a.rows() != c.rows()
    assert len(a) == 5
    # timed faults come out sorted by fire time
    times = [f.at for f in a]
    assert times == sorted(times)


def test_random_schedule_skips_kinds_without_candidates():
    sched = FaultSchedule.random(3, n_faults=10, nodes=(), queues=())
    assert all(f.kind == "registry_outage" for f in sched)


# ---------------------------------------------------------------------------
# Fault kinds at the cluster level
# ---------------------------------------------------------------------------

def _consumer_cluster(root, faults=None, num_nodes=2):
    cluster = Cluster(root, num_nodes=num_nodes, faults=faults)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    q = broker.declare_queue("orders")
    worker = HashConsumer()
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", worker, q)
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    tokens = []

    def producer():
        i = 0
        while sim.now < 30.0:
            yield 0.2
            broker.publish("orders", {"token": (i * 37) % 997})
            tokens.append((i * 37) % 997)
            i += 1

    sim.process(producer())
    return cluster, holder, tokens, worker


def test_broker_stall_delays_but_never_loses(tmp_path):
    from repro.core.workload import reference_fold

    faults = [Fault("broker_stall", at=10.0, queue="orders", duration=5.0)]
    cluster, holder, tokens, worker = _consumer_cluster(
        str(tmp_path / "reg"), faults=faults)
    sim = cluster.sim
    sim.run(until=12.0)
    depth_mid = cluster.broker.queues["orders"].depth()
    assert depth_mid > 5  # stalled: publishes pile up
    sim.run(until=40.0)
    assert cluster.broker.queues["orders"].depth() == 0  # drained after
    ref = reference_fold(HashConsumer, tokens, worker.last_msg_id)
    assert ref.state_equal(worker)  # exactly-once despite the stall


def test_registry_outage_rejects_transfers_and_recovers(tmp_path):
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2,
                      faults=[Fault("registry_outage", at=5.0,
                                    duration=10.0)])
    sim, api = cluster.sim, cluster.api
    results = {}

    def pusher(name, t0):
        yield t0
        w = HashConsumer()
        ckpt = {"state": w.state_tree(), "last_msg_id": -1}
        try:
            yield from api.build_and_push_image(ckpt, name)
            results[name] = "ok"
        except TransferAborted as exc:
            results[name] = str(exc)

    sim.process(pusher("early", 0.0))    # build 11s -> push at 11 (outage
    sim.process(pusher("during", 1.0))   # ended at 15? no: build lands at 12)
    sim.process(pusher("late", 16.0))    # after the outage: succeeds
    sim.run(until=60.0)
    assert "outage" in results["early"]   # push attempted at t=11 < 15
    assert "outage" in results["during"]
    assert results["late"] == "ok"


def test_link_degrade_scales_and_restores_capacity(tmp_path):
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2,
                      faults=[Fault("link_degrade", at=2.0, node="node0",
                                    duration=3.0, factor=0.5)])
    sim = cluster.sim
    link = cluster.topology.registry_link("node0")
    base = link.capacity_Bps
    sim.run(until=3.0)
    assert link.capacity_Bps == base * 0.5
    sim.run(until=6.0)
    assert link.capacity_Bps == base


def test_overlapping_link_degrades_compose_and_restore(tmp_path):
    """Two overlapping degrade windows on one link compose
    multiplicatively and the base capacity is restored bit-exactly when
    the LAST window ends (a stale-capture restore left the link degraded
    forever)."""
    cluster = Cluster(
        str(tmp_path / "reg"), num_nodes=2,
        faults=[Fault("link_degrade", at=2.0, node="node0", duration=6.0,
                      factor=0.25),
                Fault("link_degrade", at=4.0, node="node0", duration=10.0,
                      factor=0.5)])
    sim = cluster.sim
    link = cluster.topology.registry_link("node0")
    base = link.capacity_Bps
    sim.run(until=3.0)
    assert link.capacity_Bps == base * 0.25
    sim.run(until=5.0)
    assert link.capacity_Bps == base * 0.25 * 0.5
    sim.run(until=9.0)   # first window ended at t=8
    assert link.capacity_Bps == base * 0.125 / 0.25
    sim.run(until=15.0)  # second window ended at t=14: bit-exact base
    assert link.capacity_Bps == base


def test_aborted_push_is_still_garbage_collected(tmp_path):
    """An image whose registry write landed but whose wire transfer
    aborted (registry outage during the push) is tracked before the
    transfer and rollback still deletes it — half-pushed images must not
    leak storage."""
    # outage window 28..45 covers the first push (image build ends ~29)
    faults = [Fault("registry_outage", at=28.0, duration=17.0)]
    cluster, holder, tokens, worker = _consumer_cluster(
        str(tmp_path / "reg"), faults=faults, num_nodes=2)
    sim, api = cluster.sim, cluster.api
    sim.run(until=10.0)
    mgr = MigrationManager(api, HashConsumer, "orders")
    mgr.migrate("ms2m_individual", holder["pod"], "node1")
    with pytest.raises(MigrationError) as ei:
        sim.run(until=200.0)
    ctx = ei.value.context
    assert ctx.rolled_back
    # the manifest was written before the aborted transfer, and rollback
    # deleted it anyway: nothing left in the registry
    assert cluster.registry.list_images() == []
    assert cluster.registry.gc() == (0, 0)


def test_phase_triggered_fault_fires_on_matching_event(tmp_path):
    faults = [Fault("registry_outage", phase="checkpoint", duration=12.0)]
    r = run_migration_experiment(
        "ms2m_individual", 6.0, registry_root=str(tmp_path / "reg"),
        seed=5, faults=faults, allow_failure=True,
        policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0))
    # the checkpoint phase ends at t=18 and triggers the outage; the
    # window 18..30 covers the first push (image build ends ~29), so
    # attempt 1 aborts and the retry makes it through after the window
    assert r.report is not None and r.report.attempts >= 2
    assert r.verified


def test_permanent_crash_during_flap_window_stays_dead(tmp_path):
    """A permanent node_crash landing inside a flap's partition window
    must kill the node for good: the flap's scheduled revive cannot
    resurrect it (and its pods die at crash time, not at revive time)."""
    cluster = Cluster(
        str(tmp_path / "reg"), num_nodes=2,
        faults=[Fault("node_flap", at=4.0, node="node1", duration=10.0),
                Fault("node_crash", at=8.0, node="node1")])
    sim, api = cluster.sim, cluster.api
    q = cluster.broker.declare_queue("q")
    holder = {}

    def boot():
        pod = yield from api.create_pod("p1", "node1", HashConsumer(), q)
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    sim.run(until=6.0)
    assert not holder["pod"].deleted      # flap only stalls the pod
    sim.run(until=9.0)
    assert holder["pod"].deleted          # the crash killed it
    sim.run(until=30.0)                   # past the flap's revive time
    assert not api.nodes["node1"].alive   # permanent means permanent
    actions = [e["action"] for e in cluster.faults.log]
    assert actions == ["fired", "fired", "revive_superseded_by_crash"]


def test_permanent_crash_over_timed_crash_stays_dead(tmp_path):
    """A permanent crash fired while the node is already dead from a
    TIMED crash still declares permanence: the timed crash's scheduled
    revive must not resurrect the node."""
    cluster = Cluster(
        str(tmp_path / "reg"), num_nodes=2,
        faults=[Fault("node_crash", at=1.0, node="node1", duration=5.0),
                Fault("node_crash", at=3.0, node="node1")])
    cluster.sim.run(until=20.0)
    assert not cluster.api.nodes["node1"].alive
    actions = [e["action"] for e in cluster.faults.log]
    assert actions == ["fired", "skipped", "revive_superseded_by_crash"]


def test_link_degrade_unknown_node_is_skipped(tmp_path):
    """A typo'd node name must not silently degrade the registry's own
    intra-zone link (zone() falls back to the registry zone)."""
    cluster = Cluster(
        str(tmp_path / "reg"), num_nodes=2,
        faults=[Fault("link_degrade", at=2.0, node="nodeX", duration=5.0,
                      factor=0.1)])
    base = cluster.topology.registry_link("node0").capacity_Bps
    cluster.sim.run(until=4.0)
    assert cluster.topology.registry_link("node0").capacity_Bps == base
    assert [e["action"] for e in cluster.faults.log] == ["skipped"]


def test_rolled_back_survives_pick_target_exhaustion(tmp_path):
    """Attempt 1 rolls back cleanly, then every other target node dies so
    the retry cannot even pick a target: the failure entry must still
    report rolled_back=True (the workload WAS left rolled back) with the
    source serving — the invariant keys on workload state, not on which
    attempt happened to be terminal."""
    faults = [Fault("node_crash", at=12.0, node="node1"),
              Fault("node_crash", at=14.0, node="node2")]
    r = run_migration_experiment(
        "ms2m_precopy", 8.0, registry_root=str(tmp_path / "reg"), seed=3,
        faults=faults, allow_failure=True,
        policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0))
    assert r.failed
    f = r.failure
    assert f["rolled_back"] and f["source_serving"] and f["source_verified"]
    assert f["target_node"] is None  # the terminal attempt picked none


def test_injector_log_records_firings(tmp_path):
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2,
                      faults=[Fault("node_flap", at=3.0, node="node1",
                                    duration=2.0)])
    cluster.sim.run(until=10.0)
    actions = [(e["action"], e["kind"]) for e in cluster.faults.log]
    assert actions == [("fired", "node_flap"), ("revived", "node_flap")]
    assert cluster.api.nodes["node1"].alive


# ---------------------------------------------------------------------------
# Rollback guarantee (single migration)
# ---------------------------------------------------------------------------

def test_failed_migration_rolls_back_to_a_noop(tmp_path):
    """Kill the target node mid-restore: the attempt must be a no-op —
    source serving, no mirror, no target remnants, no leaked images."""
    faults = [Fault("node_crash", at=40.0, node="node1")]
    cluster, holder, tokens, worker = _consumer_cluster(
        str(tmp_path / "reg"), faults=faults, num_nodes=2)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    sim.run(until=10.0)
    source = holder["pod"]
    mgr = MigrationManager(api, HashConsumer, "orders")
    mgr.migrate("ms2m_individual", source, "node1")
    with pytest.raises(MigrationError) as ei:
        sim.run(until=200.0)
    ctx = ei.value.context
    assert ctx.rolled_back
    sim.run(until=200.0)  # let the restored source keep serving

    # source serving again, from the primary queue
    assert not source.deleted and source.serving and not source.paused
    assert source.queue is broker.queues["orders"]
    # no mirror left attached (no double-buffering of future publishes)
    assert broker._mirrors["orders"] == []
    # no target remnants in the control plane
    assert [p for p in api.pods if "target" in p] == []
    # every image the attempt pushed was deleted and its chunks collected
    assert cluster.registry.list_images() == []
    assert cluster.registry.gc() == (0, 0)  # nothing left to collect
    # the workload kept folding correctly after the rollback
    from repro.core.workload import reference_fold
    ref = reference_fold(HashConsumer, tokens, worker.last_msg_id)
    assert ref.state_equal(worker)


def test_statefulset_rollback_recreates_source_with_identity(tmp_path):
    """The stop-then-replay path deletes the source before the failure:
    rollback must re-create it from its live worker and re-claim the
    StatefulSet identity."""
    faults = [Fault("node_crash", at=48.0, node="node1")]
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2, faults=faults)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    q = broker.declare_queue("orders")
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", HashConsumer(), q,
                                        statefulset_identity="replica-0")
        pod.start()
        holder["pod"] = pod

    sim.process(boot())

    def producer():
        while sim.now < 120.0:
            yield 0.25
            broker.publish("orders", {"token": 7})

    sim.process(producer())
    sim.run(until=10.0)
    mgr = MigrationManager(api, HashConsumer, "orders")
    mgr.migrate("ms2m_statefulset", holder["pod"], "node1",
                statefulset_identity="replica-0")
    with pytest.raises(MigrationError) as ei:
        sim.run(until=300.0)
    ctx = ei.value.context
    assert ctx.rolled_back
    restored = ctx.restored_source
    assert restored is not None and restored.name == "c0"
    assert api.statefulsets.identities["replica-0"] == "c0"
    sim.run(until=140.0)
    assert restored.serving and restored.worker.n_processed > 0


def test_rollback_reports_false_when_source_node_is_dead(tmp_path):
    """A dead source node leaves nothing to roll back to: the failure is
    surfaced, rolled_back stays False (journal recovery's job)."""
    faults = [Fault("node_crash", at=20.0, node="node0")]  # the SOURCE
    cluster, holder, tokens, worker = _consumer_cluster(
        str(tmp_path / "reg"), faults=faults, num_nodes=2)
    sim, api = cluster.sim, cluster.api
    sim.run(until=10.0)
    mgr = MigrationManager(api, HashConsumer, "orders")
    mgr.migrate("ms2m_individual", holder["pod"], "node1")
    with pytest.raises(MigrationError) as ei:
        sim.run(until=300.0)
    assert not ei.value.context.rolled_back


# ---------------------------------------------------------------------------
# Orchestrator retry loop
# ---------------------------------------------------------------------------

def test_retry_replaces_excluding_failed_target(tmp_path):
    """Crash the pinned target node: the retry must re-place the spec on
    another node and complete, with attempts/recovered recorded."""
    faults = [Fault("node_crash", at=14.0, node="node3")]
    fleet = run_fleet_experiment(
        2, "ms2m_individual", 8.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", num_nodes=4, seed=1, faults=faults,
        allow_failures=True,
        policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0))
    assert fleet.n_failed == 0 and fleet.n_migrated == 2
    assert fleet.all_verified
    assert fleet.n_recovered == 2           # both needed a second attempt
    assert fleet.attempts == 4
    assert all(t.node.name != "node3" for t in fleet.targets)
    row = fleet.row()
    assert row["attempts"] == 4 and row["recovered"] == 2


def test_exhausted_retries_leave_source_serving(tmp_path):
    """A permanent registry outage exhausts every attempt; each failure
    entry must certify the rollback guarantee."""
    faults = [Fault("registry_outage", at=10.5, duration=500.0)]
    fleet = run_fleet_experiment(
        2, "ms2m_precopy", 8.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", num_nodes=4, seed=2, faults=faults,
        allow_failures=True,
        policy=MigrationPolicy(max_attempts=2, retry_backoff_s=1.0))
    assert fleet.n_migrated == 0 and fleet.n_failed == 2
    for f in fleet.failures:
        assert f["attempts"] == 2
        assert f["rolled_back"] and f["source_serving"]
        assert f["source_verified"]


def test_default_policy_is_single_attempt(tmp_path):
    """max_attempts defaults to 1: the legacy fail-once behaviour."""
    faults = [Fault("registry_outage", at=10.5, duration=500.0)]
    fleet = run_fleet_experiment(
        1, "ms2m_individual", 8.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", num_nodes=3, seed=0, faults=faults,
        allow_failures=True)
    assert fleet.n_failed == 1
    assert fleet.failures[0]["attempts"] == 1


def test_same_seed_fleet_rows_are_bit_identical(tmp_path):
    def run(reg):
        sched = FaultSchedule.random(
            11, n_faults=3, t_window=(10.0, 40.0), nodes=("node3",),
            queues=("orders-0", "orders-1"))
        fleet = run_fleet_experiment(
            2, "ms2m_precopy", 8.0, registry_root=reg, mode="parallel",
            num_nodes=4, seed=11, faults=sched, allow_failures=True,
            policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0))
        return json.dumps(fleet.row(), sort_keys=True)

    assert run(str(tmp_path / "a")) == run(str(tmp_path / "b"))
