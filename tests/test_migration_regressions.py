"""Regression tests for two seed bugs in the MigrationManager:

1. ``_drain_condition`` short-circuited on ``secondary.depth() == 0`` even
   while the target was still below ``up_to_id`` — a momentarily-empty
   mirror (last mirrored message in flight, mid-service) triggered a
   premature cutover before the target's state was caught up.
2. ``_sync_condition`` chained a closure onto ``source.on_processed`` per
   migration and never removed it, so repeated migrations of the same
   lineage (the orchestrator's bread and butter) kept firing stale checks
   against deleted pods.
"""
from repro.cluster.cluster import Cluster
from repro.core import HashConsumer, MigrationManager


def _mk_cluster(tmp_path):
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=3)
    cluster.broker.declare_queue("orders")
    return cluster


def test_drain_condition_waits_for_in_flight_message(tmp_path):
    cluster = _mk_cluster(tmp_path)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    sec = broker.attach_secondary("orders", "orders.sec")
    broker.publish("orders", {"token": 1})  # id 0, mirrored into sec

    worker = HashConsumer()
    holder = {}

    def boot():
        pod = yield from api.create_pod("t", "node1", worker, sec)
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    mgr = MigrationManager(api, HashConsumer, "orders")
    probe = {}

    def drain_probe():
        # pod_create_s = 3.0: the pod pops msg 0 at t=3.0 and services it
        # for processing_ms = 50ms.  Open the drain mid-service: the mirror
        # is momentarily empty but the message is in flight.
        yield 3.02
        pod = holder["pod"]
        probe["busy_at_call"] = pod.busy
        probe["depth_at_call"] = sec.depth()
        cond = mgr._drain_condition(pod, 0, sec, [])
        probe["premature"] = cond.triggered  # seed bug: True
        yield cond
        probe["last_at_trigger"] = pod.worker.last_msg_id

    sim.process(drain_probe())
    sim.run(until=10.0)

    assert probe["depth_at_call"] == 0 and probe["busy_at_call"]
    assert probe["premature"] is False  # must wait for the in-flight fold
    assert probe["last_at_trigger"] == 0  # and trigger once it lands


def test_drain_condition_still_short_circuits_when_idle(tmp_path):
    """The empty-mirror escape must survive for ids the mirror can never
    deliver (consumed from the primary before the secondary attached)."""
    cluster = _mk_cluster(tmp_path)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    sec = broker.attach_secondary("orders", "orders.sec")
    worker = HashConsumer()
    worker.last_msg_id = 3  # restored marker below the requested id
    holder = {}

    def boot():
        pod = yield from api.create_pod("t", "node1", worker, sec)
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    mgr = MigrationManager(api, HashConsumer, "orders")
    probe = {}

    def drain_probe():
        yield 5.0  # mirror empty, pod idle
        cond = mgr._drain_condition(holder["pod"], 7, sec, [])
        probe["triggered"] = cond.triggered

    sim.process(drain_probe())
    sim.run(until=6.0)
    assert probe["triggered"] is True  # no deadlock on undeliverable ids


def _run_one_migration(cluster, mgr, source, target_node):
    sim = cluster.sim
    done = mgr.migrate("ms2m_individual", source, target_node)
    sim.run(stop_when=done)
    return done.value


def test_processed_callbacks_deregistered_after_migration(tmp_path):
    cluster = _mk_cluster(tmp_path)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    stop = {"flag": False}

    def producer():
        while not stop["flag"]:
            yield 0.1
            broker.publish("orders", {"token": 42})

    sim.process(producer())
    holder = {}

    def boot():
        pod = yield from api.create_pod("consumer-0", "node0", HashConsumer(),
                                        broker.queues["orders"])
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    sim.run(until=5.0)
    source = holder["pod"]

    calls = []
    sentinel = lambda p, m: calls.append(p.name)  # noqa: E731
    source.on_processed = sentinel  # the workload's own hook

    mgr = MigrationManager(api, HashConsumer, "orders")
    rep1, target1 = _run_one_migration(cluster, mgr, source, "node1")

    # migration listeners are gone from both endpoints; the workload hook
    # survives untouched (not wrapped, not dropped)
    assert source.on_processed_listeners == []
    assert target1.on_processed_listeners == []
    assert source.on_processed is sentinel

    # second migration of the same lineage (orchestrator scenario): the
    # stale-closure leak used to fire dead-pod checks here
    rep2, target2 = _run_one_migration(cluster, mgr, target1, "node2")
    assert target1.on_processed_listeners == []
    assert target2.on_processed_listeners == []
    assert rep2.strategy == "ms2m_individual"

    stop["flag"] = True
    sim.run(until=sim.now + 1.0)
    assert target2.worker.n_processed > 0


def test_concurrent_migrations_on_one_queue_get_distinct_secondaries(tmp_path):
    """Seed bug (reachable via the orchestrator): two migrate() calls on one
    manager before either generator ran both read the post-increment ``_n``
    and attached the SAME secondary queue, double-mirroring it and
    deadlocking both migrations."""
    cluster = _mk_cluster(tmp_path)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    stop = {"flag": False}

    def producer():
        while not stop["flag"]:
            yield 0.1
            broker.publish("orders", {"token": 9})

    sim.process(producer())
    holder = {}
    for i in range(2):
        def boot(i=i):
            pod = yield from api.create_pod(
                f"c{i}", f"node{i}", HashConsumer(), broker.queues["orders"])
            pod.start()
            holder[i] = pod

        sim.process(boot())
    sim.run(until=5.0)

    mgr = MigrationManager(api, HashConsumer, "orders")
    done0 = mgr.migrate("ms2m_individual", holder[0], "node2")
    done1 = mgr.migrate("ms2m_individual", holder[1], "node2")
    sim.run(until=sim.now + 400.0)
    stop["flag"] = True

    assert done0.triggered and done1.triggered  # seed bug: neither completes
    # distinct mirrors, both detached again after cutover
    assert broker._mirrors["orders"] == []
    t0, t1 = done0.value[1], done1.value[1]
    assert t0.name != t1.name


def test_failed_migration_detaches_its_mirror(tmp_path):
    """A migration that dies mid-flight (target node killed) must not leave
    its secondary attached, or every future publish is double-buffered into
    a queue nothing drains."""
    import pytest

    cluster = _mk_cluster(tmp_path)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    stop = {"flag": False}

    def producer():
        while not stop["flag"]:
            yield 0.1
            broker.publish("orders", {"token": 5})

    sim.process(producer())
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", HashConsumer(),
                                        broker.queues["orders"])
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    sim.run(until=5.0)
    api.kill_node("node2")  # target dies before the migration starts

    mgr = MigrationManager(api, HashConsumer, "orders")
    mgr.migrate("ms2m_individual", holder["pod"], "node2")
    with pytest.raises(RuntimeError, match="dead"):
        sim.run(until=sim.now + 100.0)
    stop["flag"] = True
    assert broker._mirrors["orders"] == []  # seed bug: orphan mirror left


def test_kernel_interrupt_not_swallowed_mid_migration(tmp_path):
    """SIM001 regression: ``sim.Interrupt`` subclasses ``Exception``, so
    the broad rollback handler in ``_run_rolled_back`` used to eat a
    kernel interrupt and convert it into a MigrationError.  An interrupt
    thrown into a migrating process must propagate as-is."""
    import pytest

    from repro.cluster.sim import Interrupt

    cluster = _mk_cluster(tmp_path)
    api, broker = cluster.api, cluster.broker
    broker.publish("orders", {"token": 1})
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", HashConsumer(),
                                        broker.queues["orders"])
        pod.start()
        holder["pod"] = pod

    cluster.sim.process(boot())
    cluster.sim.run(until=5.0)

    mgr = MigrationManager(api, HashConsumer, "orders")
    gen = mgr.migration("ms2m_individual", holder["pod"], "node1")
    next(gen)  # into the strategy body
    with pytest.raises(Interrupt):
        gen.throw(Interrupt())


def test_kernel_interrupt_not_swallowed_mid_rollback(tmp_path):
    """The inner rollback-failure handler had the same hazard: an
    Interrupt arriving while ``ctx.rollback`` is yielding (deleting the
    half-built target) must propagate, not be recorded as a rollback
    error under a MigrationError."""
    import pytest

    from repro.core.migration import MigrationError
    from repro.cluster.sim import Interrupt

    cluster = _mk_cluster(tmp_path)
    api, broker = cluster.api, cluster.broker
    broker.publish("orders", {"token": 1})
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", HashConsumer(),
                                        broker.queues["orders"])
        pod.start()
        holder["pod"] = pod

    cluster.sim.process(boot())
    cluster.sim.run(until=5.0)

    mgr = MigrationManager(api, HashConsumer, "orders")
    gen = mgr.migration("ms2m_individual", holder["pod"], "node1")
    # drive the generator by hand until the target pod exists, so the
    # rollback path has remnants to clean up (and therefore yields)
    for _ in range(200):
        next(gen)
        if any(name != "c0" for name in api.pods):
            break
    else:
        raise AssertionError("target pod never appeared")
    # fail the migration: the broad handler catches this and starts
    # ctx.rollback, whose first step (deleting the target) yields
    gen.throw(RuntimeError("boom"))
    with pytest.raises(Interrupt):
        gen.throw(Interrupt())

    # sanity: the same failure WITHOUT an interrupt still rolls back into
    # a MigrationError (the fix must not weaken the rollback contract)
    gen2 = mgr.migration("ms2m_individual", holder["pod"], "node1")
    for _ in range(200):
        next(gen2)
        if "c0-target-2" in api.pods:
            break
    else:
        raise AssertionError("second target pod never appeared")
    with pytest.raises(MigrationError):
        gen2.throw(RuntimeError("boom"))
        while True:
            next(gen2)


def test_identity_handoff_rejected_for_non_statefulset_strategies(tmp_path):
    """Non-StatefulSet strategies delete the source without releasing its
    identity; passing one must fail fast instead of leaking the claim to a
    dead pod."""
    import pytest

    cluster = _mk_cluster(tmp_path)
    mgr = MigrationManager(cluster.api, HashConsumer, "orders")
    with pytest.raises(ValueError, match="ms2m_statefulset"):
        mgr.migrate("ms2m_individual", None, "node1",
                    statefulset_identity="consumer-0")
