"""Delta codecs + registry edge cases: roundtrip bit-exactness per codec
(including under full migration replay), empty/all-dirty deltas, and leaf
sizes straddling chunk boundaries."""
import numpy as np
import pytest

from repro.checkpoint import Registry
from repro.checkpoint.codecs import get_codec
from repro.core import HashConsumer, MigrationPolicy, run_migration_experiment

CB = 64 * 1024


# ---------------------------------------------------------------------------
# codec unit level
# ---------------------------------------------------------------------------

def test_xor_rle_roundtrip_sparse_and_dense():
    rng = np.random.default_rng(0)
    parent = rng.standard_normal(CB // 4).astype(np.float32)
    sparse = parent.copy()
    sparse[100:300] += 1.0
    dense = rng.standard_normal(CB // 4).astype(np.float32)
    codec = get_codec("xor_rle")
    for cur in (sparse, dense, parent):
        raw, praw = cur.tobytes(), parent.tobytes()
        blob = codec.encode(raw, praw, np.dtype(np.float32))
        assert codec.decode(blob, praw, np.dtype(np.float32)) == raw
        assert len(blob) <= len(raw) + 1  # raw-literal fallback bound
    # near-static chunk collapses to a sliver
    blob = codec.encode(sparse.tobytes(), parent.tobytes(),
                        np.dtype(np.float32))
    assert len(blob) < 0.05 * sparse.nbytes


def test_int8_codec_quantizes_float_deltas():
    rng = np.random.default_rng(1)
    parent = rng.standard_normal(CB // 4).astype(np.float32)
    cur = parent + rng.standard_normal(CB // 4).astype(np.float32) * 0.01
    codec = get_codec("int8")
    blob = codec.encode(cur.tobytes(), parent.tobytes(),
                        np.dtype(np.float32))
    assert len(blob) < 0.3 * cur.nbytes  # ~3.9x for f32
    dec = np.frombuffer(
        codec.decode(blob, parent.tobytes(), np.dtype(np.float32)),
        np.float32)
    assert not codec.lossless
    np.testing.assert_allclose(dec, cur, atol=1e-3)


# ---------------------------------------------------------------------------
# registry edge cases
# ---------------------------------------------------------------------------

def test_empty_delta_zero_dirty_chunks(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    tree = {"a": np.arange(100_000, dtype=np.float32),
            "b": np.arange(50_000, dtype=np.int64)}
    full = reg.push_image({"state": tree})
    for codec in ("none", "xor_rle", "int8"):
        delta = reg.push_delta({"state": tree}, full.image_id,
                               compression=codec)
        assert delta.delta_bytes == 0
        assert delta.wire_bytes == 0
        assert delta.written_bytes == 0
        assert not delta.lossy
        # every chunk was proven clean by fingerprint, none re-hashed
        assert delta.fp_clean_chunks == delta.num_chunks > 0
        pulled, _ = reg.pull_image(delta.image_id)
        for k, v in tree.items():
            np.testing.assert_array_equal(pulled["state"][k], v)


def test_all_dirty_delta(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    rng = np.random.default_rng(2)
    t0 = {"a": rng.standard_normal(200_000).astype(np.float32)}
    t1 = {"a": rng.standard_normal(200_000).astype(np.float32)}
    full = reg.push_image({"state": t0})
    delta = reg.push_delta({"state": t1}, full.image_id,
                           compression="xor_rle")
    assert delta.delta_bytes == t1["a"].nbytes  # every chunk dirty
    assert delta.fp_clean_chunks == 0
    # incompressible noise: the raw-literal fallback caps wire near raw
    assert delta.wire_bytes <= delta.delta_bytes + delta.num_chunks
    pulled, _ = reg.pull_image(delta.image_id)
    np.testing.assert_array_equal(pulled["state"]["a"], t1["a"])


@pytest.mark.parametrize("nbytes", [CB - 4, CB, CB + 4, 3 * CB - 100,
                                    3 * CB + 8, 36])
def test_leaf_sizes_straddling_chunk_boundaries(tmp_path, nbytes):
    reg = Registry(str(tmp_path / str(nbytes)), chunk_bytes=CB)
    n = nbytes // 4
    base = np.arange(n, dtype=np.float32)
    full = reg.push_image({"state": {"leaf": base}})
    assert full.num_chunks == -(-nbytes // CB)
    mut = base.copy()
    mut[-1] += 1.0  # dirty the (possibly short) last chunk only
    for codec in ("none", "xor_rle", "int8"):
        delta = reg.push_delta({"state": {"leaf": mut}}, full.image_id,
                               compression=codec)
        assert delta.delta_bytes == nbytes - (full.num_chunks - 1) * CB
        pulled, _ = reg.pull_image(delta.image_id)
        got = pulled["state"]["leaf"]
        if codec == "int8":
            np.testing.assert_allclose(got, mut, atol=1e-2)
        else:
            np.testing.assert_array_equal(got, mut)


def test_int8_falls_back_on_unaligned_chunk_grid(tmp_path):
    """chunk_bytes not on the dtype's element grid would split a float
    across chunks: int8 must fall back to a lossless byte codec instead
    of crashing mid-push."""
    reg = Registry(str(tmp_path), chunk_bytes=65537)
    base = {"a": np.arange(128 * 1024, dtype=np.float32)}
    full = reg.push_image({"state": base})
    mut = {"a": base["a"] + 1.0}
    delta = reg.push_delta({"state": mut}, full.image_id,
                           compression="int8")
    assert not delta.lossy  # xor_rle fallback, bit-exact
    pulled, _ = reg.pull_image(delta.image_id)
    np.testing.assert_array_equal(pulled["state"]["a"], mut["a"])


def test_dict_compression_spec_keys_state_tree(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    base = {"a": np.arange(100_000, dtype=np.float32)}
    full = reg.push_image({"state": base})
    mut = {"a": base["a"] + 0.5}
    hit = reg.push_delta({"state": mut}, full.image_id,
                         compression={"state": "int8"})
    miss = reg.push_delta({"state": mut}, full.image_id,
                          compression={"params": "int8"})
    assert hit.enc_raw_bytes > 0 and hit.lossy
    assert miss.enc_raw_bytes == 0 and not miss.lossy


def test_zero_size_leaf_roundtrip(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    tree = {"empty": np.zeros((0, 7), np.float32), "x": np.arange(10)}
    push = reg.push_image({"state": tree})
    pulled, _ = reg.pull_image(push.image_id)
    assert pulled["state"]["empty"].shape == (0, 7)
    np.testing.assert_array_equal(pulled["state"]["x"], tree["x"])


def test_fingerprint_dirty_detection_matches_hashing(tmp_path):
    """The fp fast path must pick the same dirty set (same chunk keys)
    as full host hashing would."""
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    base = {"a": np.zeros(5 * CB // 4, np.float32)}
    full = reg.push_image({"state": base})
    mut = {"a": base["a"].copy()}
    mut["a"][2 * (CB // 4) + 5] = 3.0
    with_fp = reg.push_delta({"state": mut}, full.image_id)
    without = reg.push_delta({"state": mut}, full.image_id,
                             fingerprints=False)
    assert with_fp.fp_clean_chunks > 0 and without.fp_clean_chunks == 0
    assert reg.image_chunks(with_fp.image_id) == \
        reg.image_chunks(without.image_id)
    assert with_fp.delta_bytes == without.delta_bytes


# ---------------------------------------------------------------------------
# migration level: bit-exact restores under replay, per codec
# ---------------------------------------------------------------------------

class StripedBlobConsumer(HashConsumer):
    """Hash fold + a multi-chunk blob dirtied in thin stripes."""

    def __init__(self):
        super().__init__()
        self.blob = np.zeros(1 << 19, dtype=np.float32)  # 2 MiB

    def process(self, msg):
        super().process(msg)
        i = (msg.msg_id * 512) % (len(self.blob) - 512)
        self.blob[i: i + 512] += 1.0

    def state_tree(self):
        tree = super().state_tree()
        tree["blob"] = self.blob.copy()
        return tree

    def load_state(self, tree):
        super().load_state(tree)
        self.blob = np.array(tree["blob"], dtype=np.float32)

    def state_equal(self, other, exact: bool = True):
        return (super().state_equal(other, exact)
                and np.array_equal(self.blob, other.blob))


@pytest.mark.parametrize("codec", ["none", "xor_rle", "int8", "auto"])
def test_precopy_migration_bit_exact_per_codec(tmp_path, codec):
    r = run_migration_experiment(
        "ms2m_precopy", 10.0, registry_root=str(tmp_path / "reg"),
        seed=2, worker_factory=StripedBlobConsumer, chunk_bytes=CB,
        policy=MigrationPolicy(compression=codec, precopy_max_rounds=3))
    assert r.verified and r.report.state_verified
    row = r.row()
    assert row["compression"] == codec
    assert row["image_wire_bytes"] <= row["image_raw_bytes"]
    if codec == "int8":
        # lossy rounds must be closed by the lossless exact flush
        kinds = [e.kind for e in r.report.events]
        assert "precopy_exact_flush" in kinds
        assert r.report.precopy_round_dirty[-1] == 0


def test_statefulset_precopy_optin_with_compression(tmp_path):
    r = run_migration_experiment(
        "ms2m_statefulset", 12.0, registry_root=str(tmp_path / "reg"),
        seed=3, worker_factory=StripedBlobConsumer, chunk_bytes=CB,
        policy=MigrationPolicy(precopy=True, compression="xor_rle"))
    assert r.verified
    assert r.report.precopy_rounds >= 1


def test_unknown_codec_rejected():
    with pytest.raises(ValueError):
        MigrationPolicy(compression="gzip")
    with pytest.raises(ValueError):
        MigrationPolicy(compression={"state": "zstd"})
