"""Delta codecs + registry edge cases: roundtrip bit-exactness per codec
(including under full migration replay), empty/all-dirty deltas, leaf
sizes straddling chunk boundaries, and the property-based host-codec
suite that serves as the pinned oracle for the fused kernel path
(tests/test_codec_kernels.py)."""
import numpy as np
import pytest

from repro.checkpoint import Registry
from repro.checkpoint.codecs import (
    _RAW_FLAG,
    _RLE_FLAG,
    _rle_decode,
    _rle_encode,
    get_codec,
    resolve_compression,
)
from repro.core import HashConsumer, MigrationPolicy, run_migration_experiment

try:
    from hypothesis import given, settings
    import conftest as _strat
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CB = 64 * 1024


# ---------------------------------------------------------------------------
# codec unit level
# ---------------------------------------------------------------------------

def test_xor_rle_roundtrip_sparse_and_dense():
    rng = np.random.default_rng(0)
    parent = rng.standard_normal(CB // 4).astype(np.float32)
    sparse = parent.copy()
    sparse[100:300] += 1.0
    dense = rng.standard_normal(CB // 4).astype(np.float32)
    codec = get_codec("xor_rle")
    for cur in (sparse, dense, parent):
        raw, praw = cur.tobytes(), parent.tobytes()
        blob = codec.encode(raw, praw, np.dtype(np.float32))
        assert codec.decode(blob, praw, np.dtype(np.float32)) == raw
        assert len(blob) <= len(raw) + 1  # raw-literal fallback bound
    # near-static chunk collapses to a sliver
    blob = codec.encode(sparse.tobytes(), parent.tobytes(),
                        np.dtype(np.float32))
    assert len(blob) < 0.05 * sparse.nbytes


def test_int8_codec_quantizes_float_deltas():
    rng = np.random.default_rng(1)
    parent = rng.standard_normal(CB // 4).astype(np.float32)
    cur = parent + rng.standard_normal(CB // 4).astype(np.float32) * 0.01
    codec = get_codec("int8")
    blob = codec.encode(cur.tobytes(), parent.tobytes(),
                        np.dtype(np.float32))
    assert len(blob) < 0.3 * cur.nbytes  # ~3.9x for f32
    dec = np.frombuffer(
        codec.decode(blob, parent.tobytes(), np.dtype(np.float32)),
        np.float32)
    assert not codec.lossless
    np.testing.assert_allclose(dec, cur, atol=1e-3)


# ---------------------------------------------------------------------------
# registry edge cases
# ---------------------------------------------------------------------------

def test_empty_delta_zero_dirty_chunks(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    tree = {"a": np.arange(100_000, dtype=np.float32),
            "b": np.arange(50_000, dtype=np.int64)}
    full = reg.push_image({"state": tree})
    for codec in ("none", "xor_rle", "int8"):
        delta = reg.push_delta({"state": tree}, full.image_id,
                               compression=codec)
        assert delta.delta_bytes == 0
        assert delta.wire_bytes == 0
        assert delta.written_bytes == 0
        assert not delta.lossy
        # every chunk was proven clean by fingerprint, none re-hashed
        assert delta.fp_clean_chunks == delta.num_chunks > 0
        pulled, _ = reg.pull_image(delta.image_id)
        for k, v in tree.items():
            np.testing.assert_array_equal(pulled["state"][k], v)


def test_all_dirty_delta(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    rng = np.random.default_rng(2)
    t0 = {"a": rng.standard_normal(200_000).astype(np.float32)}
    t1 = {"a": rng.standard_normal(200_000).astype(np.float32)}
    full = reg.push_image({"state": t0})
    delta = reg.push_delta({"state": t1}, full.image_id,
                           compression="xor_rle")
    assert delta.delta_bytes == t1["a"].nbytes  # every chunk dirty
    assert delta.fp_clean_chunks == 0
    # incompressible noise: the raw-literal fallback caps wire near raw
    assert delta.wire_bytes <= delta.delta_bytes + delta.num_chunks
    pulled, _ = reg.pull_image(delta.image_id)
    np.testing.assert_array_equal(pulled["state"]["a"], t1["a"])


@pytest.mark.parametrize("nbytes", [CB - 4, CB, CB + 4, 3 * CB - 100,
                                    3 * CB + 8, 36])
def test_leaf_sizes_straddling_chunk_boundaries(tmp_path, nbytes):
    reg = Registry(str(tmp_path / str(nbytes)), chunk_bytes=CB)
    n = nbytes // 4
    base = np.arange(n, dtype=np.float32)
    full = reg.push_image({"state": {"leaf": base}})
    assert full.num_chunks == -(-nbytes // CB)
    mut = base.copy()
    mut[-1] += 1.0  # dirty the (possibly short) last chunk only
    for codec in ("none", "xor_rle", "int8"):
        delta = reg.push_delta({"state": {"leaf": mut}}, full.image_id,
                               compression=codec)
        assert delta.delta_bytes == nbytes - (full.num_chunks - 1) * CB
        pulled, _ = reg.pull_image(delta.image_id)
        got = pulled["state"]["leaf"]
        if codec == "int8":
            np.testing.assert_allclose(got, mut, atol=1e-2)
        else:
            np.testing.assert_array_equal(got, mut)


def test_int8_falls_back_on_unaligned_chunk_grid(tmp_path):
    """chunk_bytes not on the dtype's element grid would split a float
    across chunks: int8 must fall back to a lossless byte codec instead
    of crashing mid-push."""
    reg = Registry(str(tmp_path), chunk_bytes=65537)
    base = {"a": np.arange(128 * 1024, dtype=np.float32)}
    full = reg.push_image({"state": base})
    mut = {"a": base["a"] + 1.0}
    delta = reg.push_delta({"state": mut}, full.image_id,
                           compression="int8")
    assert not delta.lossy  # xor_rle fallback, bit-exact
    pulled, _ = reg.pull_image(delta.image_id)
    np.testing.assert_array_equal(pulled["state"]["a"], mut["a"])


def test_dict_compression_spec_keys_state_tree(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    base = {"a": np.arange(100_000, dtype=np.float32)}
    full = reg.push_image({"state": base})
    mut = {"a": base["a"] + 0.5}
    hit = reg.push_delta({"state": mut}, full.image_id,
                         compression={"state": "int8"})
    miss = reg.push_delta({"state": mut}, full.image_id,
                          compression={"params": "int8"})
    assert hit.enc_raw_bytes > 0 and hit.lossy
    assert miss.enc_raw_bytes == 0 and not miss.lossy


def test_zero_size_leaf_roundtrip(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    tree = {"empty": np.zeros((0, 7), np.float32), "x": np.arange(10)}
    push = reg.push_image({"state": tree})
    pulled, _ = reg.pull_image(push.image_id)
    assert pulled["state"]["empty"].shape == (0, 7)
    np.testing.assert_array_equal(pulled["state"]["x"], tree["x"])


def test_fingerprint_dirty_detection_matches_hashing(tmp_path):
    """The fp fast path must pick the same dirty set (same chunk keys)
    as full host hashing would."""
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    base = {"a": np.zeros(5 * CB // 4, np.float32)}
    full = reg.push_image({"state": base})
    mut = {"a": base["a"].copy()}
    mut["a"][2 * (CB // 4) + 5] = 3.0
    with_fp = reg.push_delta({"state": mut}, full.image_id)
    without = reg.push_delta({"state": mut}, full.image_id,
                             fingerprints=False)
    assert with_fp.fp_clean_chunks > 0 and without.fp_clean_chunks == 0
    assert reg.image_chunks(with_fp.image_id) == \
        reg.image_chunks(without.image_id)
    assert with_fp.delta_bytes == without.delta_bytes


# ---------------------------------------------------------------------------
# migration level: bit-exact restores under replay, per codec
# ---------------------------------------------------------------------------

class StripedBlobConsumer(HashConsumer):
    """Hash fold + a multi-chunk blob dirtied in thin stripes."""

    def __init__(self):
        super().__init__()
        self.blob = np.zeros(1 << 19, dtype=np.float32)  # 2 MiB

    def process(self, msg):
        super().process(msg)
        i = (msg.msg_id * 512) % (len(self.blob) - 512)
        self.blob[i: i + 512] += 1.0

    def state_tree(self):
        tree = super().state_tree()
        tree["blob"] = self.blob.copy()
        return tree

    def load_state(self, tree):
        super().load_state(tree)
        self.blob = np.array(tree["blob"], dtype=np.float32)

    def state_equal(self, other, exact: bool = True):
        return (super().state_equal(other, exact)
                and np.array_equal(self.blob, other.blob))


@pytest.mark.parametrize("codec", ["none", "xor_rle", "int8", "auto"])
def test_precopy_migration_bit_exact_per_codec(tmp_path, codec):
    r = run_migration_experiment(
        "ms2m_precopy", 10.0, registry_root=str(tmp_path / "reg"),
        seed=2, worker_factory=StripedBlobConsumer, chunk_bytes=CB,
        policy=MigrationPolicy(compression=codec, precopy_max_rounds=3))
    assert r.verified and r.report.state_verified
    row = r.row()
    assert row["compression"] == codec
    assert row["image_wire_bytes"] <= row["image_raw_bytes"]
    if codec == "int8":
        # lossy rounds must be closed by the lossless exact flush
        kinds = [e.kind for e in r.report.events]
        assert "precopy_exact_flush" in kinds
        assert r.report.precopy_round_dirty[-1] == 0


def test_statefulset_precopy_optin_with_compression(tmp_path):
    r = run_migration_experiment(
        "ms2m_statefulset", 12.0, registry_root=str(tmp_path / "reg"),
        seed=3, worker_factory=StripedBlobConsumer, chunk_bytes=CB,
        policy=MigrationPolicy(precopy=True, compression="xor_rle"))
    assert r.verified
    assert r.report.precopy_rounds >= 1


def test_unknown_codec_rejected():
    with pytest.raises(ValueError):
        MigrationPolicy(compression="gzip")
    with pytest.raises(ValueError):
        MigrationPolicy(compression={"state": "zstd"})


# ---------------------------------------------------------------------------
# codec-name validation: unknown names must fail early with ValueError
# ---------------------------------------------------------------------------

def test_get_codec_unknown_name_raises_value_error():
    """get_codec used to raise a bare KeyError deep inside a push for
    names that slipped past validation ('auto' included — it's a spec,
    not a concrete codec)."""
    with pytest.raises(ValueError, match="unknown codec 'zstd'"):
        get_codec("zstd")
    with pytest.raises(ValueError, match="resolve_compression"):
        get_codec("auto")


def test_resolve_compression_rejects_unknown_resolved_entry():
    """A dict spec naming an unknown codec for the pushed tree must fail
    at resolve time with ValueError, not silently map to a fallback (or
    KeyError at push time)."""
    with pytest.raises(ValueError, match="zstd"):
        resolve_compression({"state": "zstd"}, "state",
                            np.dtype(np.float32), True, True,
                            chunk_bytes=CB)
    # entries for *other* trees don't affect this tree (it defaults to
    # "none"), matching the documented dict semantics
    assert resolve_compression({"params": "int8"}, "state",
                               np.dtype(np.float32), True, True,
                               chunk_bytes=CB) == "none"


def test_push_with_unknown_dict_codec_raises_value_error(tmp_path):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    base = {"a": np.arange(100_000, dtype=np.float32)}
    full = reg.push_image({"state": base})
    with pytest.raises(ValueError, match="zstd"):
        reg.push_delta({"state": {"a": base["a"] + 1}}, full.image_id,
                       compression={"state": "zstd"})


# ---------------------------------------------------------------------------
# RLE layer boundary cases (deterministic)
# ---------------------------------------------------------------------------

def test_rle_empty_and_single_byte():
    assert _rle_encode(np.zeros(100, np.uint8)) == b""
    x = np.zeros(100, np.uint8)
    x[42] = 7
    blob = _rle_encode(x)
    assert len(blob) == 9  # one (zrun, lit_len, 1 byte) token
    np.testing.assert_array_equal(_rle_decode(blob, 100), x)


def test_rle_gap_absorption_threshold():
    """Zero gaps <= 16 bytes are absorbed into one literal (a token
    header costs 8 bytes); wider gaps split tokens."""
    near = np.zeros(200, np.uint8)
    near[10] = near[10 + 16] = 1     # gap of 15 zeros: absorbed
    far = np.zeros(200, np.uint8)
    far[10] = far[10 + 17] = 1       # gap of 16 zeros: split
    blob_near, blob_far = _rle_encode(near), _rle_encode(far)
    assert len(blob_near) == 8 + 17  # one token spanning the gap
    assert len(blob_far) == 2 * 9    # two single-byte tokens
    np.testing.assert_array_equal(_rle_decode(blob_near, 200), near)
    np.testing.assert_array_equal(_rle_decode(blob_far, 200), far)


def test_xor_rle_literal_fallback_boundary():
    """Exactly at len(rle)+1 >= len(raw) the codec must emit the raw
    literal (wire never exceeds raw+1); just under it, the RLE stream."""
    codec = get_codec("xor_rle")
    parent = np.zeros(64, np.uint8)
    dense = np.arange(1, 65, dtype=np.uint8)  # all 64 bytes dirty
    blob = codec.encode(dense.tobytes(), parent.tobytes(),
                        np.dtype(np.uint8))
    assert blob[:1] == _RAW_FLAG and len(blob) == 65
    sparse = np.zeros(64, np.uint8)
    sparse[5] = 9
    blob = codec.encode(sparse.tobytes(), parent.tobytes(),
                        np.dtype(np.uint8))
    assert blob[:1] == _RLE_FLAG and len(blob) == 10
    for cur in (dense, sparse):
        enc = codec.encode(cur.tobytes(), parent.tobytes(),
                           np.dtype(np.uint8))
        assert codec.decode(enc, parent.tobytes(),
                            np.dtype(np.uint8)) == cur.tobytes()


# ---------------------------------------------------------------------------
# int8 error feedback: lossy chain closed by a bit-exact lossless flush
# ---------------------------------------------------------------------------

def test_int8_error_feedback_exact_flush_restores_bit_exact(tmp_path):
    """N lossy int8 rounds accumulate bounded quantization error (each
    round re-encodes against the receiver's lossy reconstruction — the
    EF trick), and one exact=True flush lands the receiver on the pushed
    state bit-for-bit."""
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    rng = np.random.default_rng(5)
    cur = rng.standard_normal(3 * CB // 4).astype(np.float32)
    parent_id = reg.push_image({"state": {"a": cur}}).image_id
    for _ in range(4):
        cur = cur + rng.standard_normal(cur.size).astype(np.float32) * 0.01
        rep = reg.push_delta({"state": {"a": cur}}, parent_id,
                             compression="int8")
        assert rep.lossy
        pulled, _ = reg.pull_image(rep.image_id)
        got = pulled["state"]["a"]
        assert not np.array_equal(got, cur)          # genuinely lossy
        # EF bound: reconstruction error stays one quant step, it does
        # not compound across rounds
        assert np.max(np.abs(got - cur)) < 1e-3
        parent_id = rep.image_id
    flush = reg.push_delta({"state": {"a": cur}}, parent_id,
                           compression="int8", exact=True)
    assert not flush.lossy
    pulled, _ = reg.pull_image(flush.image_id)
    np.testing.assert_array_equal(pulled["state"]["a"], cur)


# ---------------------------------------------------------------------------
# property-based suite (hypothesis; the kernel path's pinned host oracle)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(pair=_strat.codec_leaf_pairs())
    def test_xor_rle_roundtrip_property(pair):
        cur, parent = pair
        codec = get_codec("xor_rle")
        raw, praw = cur.tobytes(), parent.tobytes()
        blob = codec.encode(raw, praw, np.dtype(np.float32))
        assert codec.decode(blob, praw, np.dtype(np.float32)) == raw
        assert len(blob) <= len(raw) + 1

    @settings(max_examples=40, deadline=None)
    @given(x=_strat.sparse_byte_vectors())
    def test_rle_roundtrip_property(x):
        np.testing.assert_array_equal(
            _rle_decode(_rle_encode(x), len(x)), x)

    @settings(max_examples=30, deadline=None)
    @given(pair=_strat.codec_leaf_pairs(max_elems=2048))
    def test_int8_decode_error_bounded_property(pair):
        """decode(encode(cur)) deviates from cur by at most one quant
        step of the largest per-block delta (scale = max|delta|/127)."""
        cur, parent = pair
        codec = get_codec("int8")
        raw, praw = cur.tobytes(), parent.tobytes()
        blob = codec.encode(raw, praw, np.dtype(np.float32))
        dec = np.frombuffer(codec.decode(blob, praw, np.dtype(np.float32)),
                            np.float32)
        step = np.max(np.abs(cur - parent)) / 127.0
        assert np.max(np.abs(dec - cur)) <= step * 1.01 + 1e-7
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_codec_property_suite_requires_hypothesis():
        pass
