"""Regression tests for the rate-estimator bug class the rebalance
controller would otherwise inherit (no hypothesis dependency — these run
in every environment):

  * EWMA warm-up bias — the estimator must seed from the first real
    inter-event interval, not blend against a fake 0.0 starting rate;
  * evidence gating — the cutoff controller must gate on completed
    observation *count*, not elapsed span;
  * float-truthiness — a converged near-zero λ̂ must be returned, not
    silently swallowed into the fallback;
  * ``MigrationContext.observed_rates`` — the no-cutoff path must report
    a windowed *recent* arrival rate, not the lifetime average (which
    reads a spike an hour ago and a spike right now the same).
"""
import pytest

from repro.cluster.sim import Sim
from repro.core.cutoff import CutoffController, RateEstimator
from repro.core.strategy import recent_arrival_rate


# -- EWMA warm-up bias -------------------------------------------------------

def test_rate_estimator_seeds_from_first_interval():
    """Blending the first observation against a fake 0.0 starting rate
    biased the estimate low for the first several half-lives — exactly
    the window a short migration reads it in."""
    est = RateEstimator(halflife=10.0)
    est.observe(0.0)
    assert not est.has_estimate  # no interval yet
    est.observe(0.1)
    assert est.has_estimate
    assert est.rate == pytest.approx(10.0)  # exactly 1/dt, no zero bias


def test_rate_estimator_counts_completed_intervals():
    est = RateEstimator()
    assert est.n_obs == 0
    for k in range(5):
        est.observe(k * 1.0)
    assert est.n_obs == 4  # the first observe starts the clock


def test_rate_estimator_converges_quickly_after_seeding():
    """With correct seeding, 50 steady observations land within 5% — the
    zero-seeded version needed hundreds to shake off the bias."""
    est = RateEstimator(halflife=2.0)
    t = 0.0
    for _ in range(50):
        t += 0.1
        est.observe(t)
    assert est.rate == pytest.approx(10.0, rel=0.05)


# -- evidence gating ---------------------------------------------------------

def test_controller_gates_on_observation_count_not_span():
    """Two observations 30 s apart are one interval of evidence, not
    convergence: an elapsed-span gate would trust them."""
    c = CutoffController(t_replay_max=10.0, mu_fallback=20.0,
                         lam_fallback=5.0, use_estimates=True,
                         min_observations=30)
    c.observe_arrival(0.0)
    c.observe_arrival(30.0)  # long span, single interval
    assert c.lam_est.n_obs == 1
    assert c.lam == 5.0  # still the fallback
    t = 30.0
    for _ in range(30):  # cross the evidence gate
        t += 0.1
        c.observe_arrival(t)
    assert c.lam_est.n_obs >= c.min_observations
    assert c.lam == c.lam_est.rate  # gate open: the estimate, not 5.0
    for _ in range(600):  # several half-lives of steady 10/s evidence
        t += 0.1
        c.observe_arrival(t)
    assert c.lam == pytest.approx(10.0, rel=0.2)


def test_ungated_estimates_never_leak_without_use_estimates():
    c = CutoffController(t_replay_max=10.0, mu_fallback=20.0,
                         lam_fallback=5.0)  # use_estimates defaults False
    t = 0.0
    for _ in range(100):
        t += 0.1
        c.observe_arrival(t)
        c.observe_service(t)
    assert c.lam == 5.0 and c.mu == 20.0  # observability only


def test_converged_tiny_rate_is_not_swallowed():
    """A legitimately converged near-zero λ̂ must be returned: float
    truthiness on the estimate would silently fall back and shrink the
    cutoff threshold's denominator."""
    c = CutoffController(t_replay_max=10.0, mu_fallback=20.0,
                         lam_fallback=5.0, use_estimates=True,
                         min_observations=10)
    t = 0.0
    for _ in range(12):
        t += 1000.0  # one arrival every 1000 s: λ = 1e-3
        c.observe_arrival(t)
    assert c.lam == pytest.approx(1e-3, rel=1e-6)
    assert c.lam != c.lam_fallback


# -- windowed λ̂ on the primary queue (observed_rates' no-cutoff path) -------

def _queue_with_arrivals(sim: Sim, times):
    from repro.broker.broker import Broker

    broker = Broker(sim)
    q = broker.declare_queue("orders")
    for t in times:
        sim.run(until=t)
        broker.publish("orders", {"token": 1})
    return q


def test_recent_arrival_rate_reflects_a_spike():
    """100 s of 1 msg/s followed by a 10 msg/s spike in the last 5 s: the
    lifetime average (~1.4/s) buries the spike; the windowed estimate
    must report the recent regime."""
    sim = Sim()
    slow = [float(t) for t in range(1, 101)]            # 1/s for 100 s
    fast = [100.0 + 0.1 * k for k in range(1, 51)]      # 10/s for 5 s
    q = _queue_with_arrivals(sim, slow + fast)
    sim.run(until=105.0)
    lam = recent_arrival_rate(q, None, 105.0, halflife=2.0)
    lifetime = q.total_published / 105.0
    assert lifetime < 2.0
    assert lam > 5.0            # the spike dominates the window
    assert lam > 3.0 * lifetime


def test_recent_arrival_rate_matches_steady_rate():
    sim = Sim()
    q = _queue_with_arrivals(sim, [0.25 * k for k in range(1, 401)])
    sim.run(until=100.0)
    lam = recent_arrival_rate(q, None, 100.0)
    assert lam == pytest.approx(4.0, rel=0.1)


def test_recent_arrival_rate_falls_back_with_no_samples():
    sim = Sim()
    from repro.broker.broker import Broker

    q = Broker(sim).declare_queue("empty")
    assert recent_arrival_rate(q, None, 50.0) == 0.0
