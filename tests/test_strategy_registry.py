"""The pluggable strategy registry + declarative MigrationPolicy API:
built-in registration, custom strategies with zero manager-core edits,
legacy-kwarg compatibility, the structured MigrationEvent stream, and the
telemetry-driven ms2m_adaptive scheme."""
import dataclasses

import numpy as np
import pytest

from repro.cluster.cluster import Cluster, TimingConstants
from repro.core import (
    HashConsumer,
    MigrationManager,
    MigrationPolicy,
    MigrationStrategy,
    available_strategies,
    choose_adaptive_strategy,
    get_strategy,
    register_strategy,
    run_fleet_experiment,
    run_migration_experiment,
)

BUILTINS = ("stop_and_copy", "ms2m_individual", "ms2m_cutoff",
            "ms2m_statefulset", "ms2m_precopy", "ms2m_adaptive")


# ---------------------------------------------------------------------------
# Registry surface
# ---------------------------------------------------------------------------

def test_builtin_strategies_registered():
    names = available_strategies()
    for name in BUILTINS:
        assert name in names
    assert get_strategy("ms2m_cutoff").wants_cutoff
    assert get_strategy("ms2m_statefulset").handles_identity
    assert not get_strategy("ms2m_individual").handles_identity


def test_unknown_strategy_lists_available(tmp_path):
    with pytest.raises(ValueError, match="ms2m_individual"):
        get_strategy("ms2m_nope")
    mgr = MigrationManager(Cluster(str(tmp_path)).api, HashConsumer, "q")
    with pytest.raises(ValueError, match="unknown migration strategy"):
        mgr.migrate("ms2m_nope", None, "node1")


def test_misconfigured_cutoff_leaves_no_mirror(tmp_path):
    """ms2m_cutoff without a CutoffController fails fast, and the failure
    must not leave a secondary queue attached (double-buffer leak)."""
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    broker = cluster.broker
    broker.declare_queue("orders")
    holder = {}

    def boot():
        pod = yield from cluster.api.create_pod(
            "c0", "node0", HashConsumer(), broker.queues["orders"])
        pod.start()
        holder["pod"] = pod

    cluster.sim.process(boot())
    cluster.sim.run(until=5.0)

    mgr = MigrationManager(cluster.api, HashConsumer, "orders")  # no cutoff
    mgr.migrate("ms2m_cutoff", holder["pod"], "node1")
    # the failure is rolled back and re-raised as MigrationError (its
    # message carries the original assertion text)
    from repro.core import MigrationError
    with pytest.raises(MigrationError, match="CutoffController"):
        cluster.sim.run(until=10.0)
    assert broker._mirrors["orders"] == []
    # rollback left the source serving
    assert holder["pod"].serving and not holder["pod"].deleted


def test_custom_strategy_runs_through_harness_unchanged(tmp_path):
    """Extensibility proof: a scheme registered from *outside* the core
    runs through run_migration_experiment by name and verifies bit-exact —
    no manager / harness edits."""

    @register_strategy("test_eager_stop_and_copy")
    class EagerStopAndCopy(MigrationStrategy):
        # stop-and-copy but with the pre-copy transfer engine: the pod is
        # paused, so the single delta round finds nothing dirty
        def run(self, ctx):
            t = ctx.api.timings
            down0 = ctx.sim.now
            ctx.source.pause()
            push = yield from ctx.transfer(
                True, f"{ctx.primary_queue}-x{ctx.n}",
                f"{ctx.primary_queue}-x{ctx.n}")
            target = yield from ctx.restore_target(
                push, ctx.broker.queues[ctx.primary_queue], replay=False)
            t0 = ctx.sim.now
            yield from ctx.teardown_source()
            yield t.route_switch_s
            target.start()
            ctx.phase("cutover", t0)
            ctx.report.downtime = ctx.sim.now - down0
            ctx.finish(target)
            return ctx.report, target

    r = run_migration_experiment(
        "test_eager_stop_and_copy", 6.0,
        registry_root=str(tmp_path / "reg"), seed=5)
    assert r.verified
    assert r.report.strategy == "test_eager_stop_and_copy"
    assert r.report.precopy_round_dirty[0] >= 0


# ---------------------------------------------------------------------------
# MigrationPolicy + legacy-kwarg compatibility
# ---------------------------------------------------------------------------

def test_policy_resolve_folds_legacy_kwargs():
    pol = MigrationPolicy.resolve(None, precopy=True, precopy_max_rounds=2)
    assert pol.precopy and pol.precopy_max_rounds == 2
    base = MigrationPolicy(batched_replay=True, replay_speedup=3.0)
    assert MigrationPolicy.resolve(base).replay_speedup == 3.0
    # None means "unset": the base policy value survives
    assert MigrationPolicy.resolve(base, replay_speedup=None).batched_replay
    with pytest.raises(TypeError, match="unknown migration policy"):
        MigrationPolicy.resolve(None, not_a_knob=1)


def test_policy_clamps_replay_speedup():
    assert MigrationPolicy(replay_speedup=0.25).replay_speedup == 1.0


def test_manager_legacy_kwargs_become_policy(tmp_path):
    mgr = MigrationManager(Cluster(str(tmp_path)).api, HashConsumer, "q",
                           precopy=True, precopy_max_rounds=7,
                           batched_replay=True, replay_speedup=2.5)
    assert mgr.policy == MigrationPolicy(precopy=True, precopy_max_rounds=7,
                                         batched_replay=True,
                                         replay_speedup=2.5)
    # legacy attribute views still answer
    assert mgr.precopy and mgr.precopy_max_rounds == 7
    assert mgr.batched_replay and mgr.replay_speedup == 2.5


def test_experiment_policy_object_equivalent_to_legacy_kwargs(tmp_path):
    legacy = run_migration_experiment(
        "ms2m_statefulset", 8.0, registry_root=str(tmp_path / "a"), seed=1,
        precopy=True, manager_kwargs={"precopy_max_rounds": 2})
    declarative = run_migration_experiment(
        "ms2m_statefulset", 8.0, registry_root=str(tmp_path / "b"), seed=1,
        policy=MigrationPolicy(precopy=True, precopy_max_rounds=2))
    assert legacy.verified and declarative.verified
    assert legacy.report.phases == declarative.report.phases
    assert legacy.downtime == declarative.downtime
    assert (legacy.report.precopy_round_bytes
            == declarative.report.precopy_round_bytes)


# ---------------------------------------------------------------------------
# MigrationEvent trace stream
# ---------------------------------------------------------------------------

def test_event_stream_carries_phases_and_cutoff(tmp_path):
    r = run_migration_experiment(
        "ms2m_cutoff", 18.0, registry_root=str(tmp_path / "reg"), seed=1,
        t_replay_max=20.0)
    assert r.report.cutoff_fired
    kinds = [e.kind for e in r.report.events]
    assert "cutoff_fired" in kinds and "migration_end" in kinds
    fired = next(e for e in r.report.events if e.kind == "cutoff_fired")
    assert fired.data["cutoff_id"] == r.report.cutoff_id
    # the phases dict is a pure view over phase events
    phase_events = [e for e in r.report.events if e.kind == "phase"]
    assert r.report.phases == {
        name: sum(e.data["duration"] for e in phase_events
                  if e.data["phase"] == name)
        for name in {e.data["phase"] for e in phase_events}}
    # events are time-ordered rows
    rows = r.report.event_rows()
    assert all(a["t"] <= b["t"] for a, b in zip(rows, rows[1:]))


def test_precopy_rounds_traced_and_reported(tmp_path):
    r = run_migration_experiment(
        "ms2m_precopy", 10.0, registry_root=str(tmp_path / "reg"), seed=0)
    assert r.verified
    rounds = [e for e in r.report.events if e.kind == "precopy_round"]
    assert len(rounds) == r.report.precopy_rounds + 1
    assert [e.data["dirty"] for e in rounds] == r.report.precopy_round_dirty
    row = r.row()
    assert row["precopy_round_dirty"] == r.report.precopy_round_dirty
    assert row["state_verified"] is True


# ---------------------------------------------------------------------------
# ms2m_adaptive
# ---------------------------------------------------------------------------

def test_choose_adaptive_strategy_decision_table():
    # saturated: live sync can't converge
    name, why = choose_adaptive_strategy(
        19.0, 20.0, fixed_s=46.0, wire_s=0.1, t_replay_max=45.0, rho_max=0.9)
    assert name == "ms2m_cutoff" and why["reason"] == "unstable_for_live_sync"
    # byte-dominated transfer: iterative pre-copy regime
    name, why = choose_adaptive_strategy(
        4.0, 20.0, fixed_s=9.0, wire_s=20.0, t_replay_max=45.0)
    assert name == "ms2m_precopy" and why["reason"] == "byte_dominated_transfer"
    # stable but catch-up exceeds the bound
    name, why = choose_adaptive_strategy(
        16.0, 20.0, fixed_s=46.0, wire_s=0.1, t_replay_max=45.0)
    assert name == "ms2m_cutoff"
    assert why["reason"] == "catchup_exceeds_replay_bound"
    # easy regime
    name, why = choose_adaptive_strategy(
        4.0, 20.0, fixed_s=46.0, wire_s=0.1, t_replay_max=45.0)
    assert name == "ms2m_individual" and why["reason"] == "stable_and_cheap"


def _adaptive_choice(result):
    ev = [e for e in result.report.events if e.kind == "adaptive_choice"]
    assert len(ev) == 1
    return ev[0].data


def test_adaptive_low_rate_picks_individual_and_verifies(tmp_path):
    r = run_migration_experiment(
        "ms2m_adaptive", 4.0, registry_root=str(tmp_path / "reg"), seed=2)
    assert r.verified  # bit-exact against the reference fold
    assert r.report.strategy == "ms2m_adaptive"
    assert _adaptive_choice(r)["chosen"] == "ms2m_individual"


def test_adaptive_saturated_rate_picks_cutoff(tmp_path):
    r = run_migration_experiment(
        "ms2m_adaptive", 19.0, registry_root=str(tmp_path / "reg"), seed=2)
    assert r.verified
    assert _adaptive_choice(r)["chosen"] == "ms2m_cutoff"
    assert r.report.cutoff_fired  # the delegate's telemetry flows through


class BlobConsumer(HashConsumer):
    """Hash fold plus a mostly-static 8 MiB blob: byte-dominated images."""

    def __init__(self):
        super().__init__()
        self.blob = np.zeros(1 << 21, dtype=np.float32)

    def process(self, msg):
        super().process(msg)
        i = (msg.msg_id * 1024) % (len(self.blob) - 1024)
        self.blob[i: i + 1024] += 1.0

    def state_tree(self):
        tree = super().state_tree()
        tree["blob"] = self.blob.copy()
        return tree

    def load_state(self, tree):
        super().load_state(tree)
        self.blob = np.array(tree["blob"], dtype=np.float32)

    def state_equal(self, other, exact: bool = True):
        return (super().state_equal(other, exact)
                and np.array_equal(self.blob, other.blob))


def test_adaptive_byte_dominated_picks_precopy(tmp_path):
    wan = TimingConstants(checkpoint_s=1.0, image_build_s=2.0,
                          delta_build_s=0.5, push_base_s=0.5,
                          pull_base_s=0.5, restore_s=2.0,
                          registry_bw_Bps=1e6)
    r = run_migration_experiment(
        "ms2m_adaptive", 6.0, registry_root=str(tmp_path / "reg"), seed=3,
        timings=wan, worker_factory=BlobConsumer, chunk_bytes=64 * 1024)
    assert r.verified
    choice = _adaptive_choice(r)
    assert choice["chosen"] == "ms2m_precopy"
    assert choice["wire_s"] > choice["fixed_s"]
    assert r.report.precopy_rounds >= 1


def test_adaptive_runs_in_fleet_harness(tmp_path):
    fleet = run_fleet_experiment(
        3, "ms2m_adaptive", 8.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", max_concurrent=3, seed=4)
    assert fleet.n_migrated == 3 and fleet.n_failed == 0
    assert fleet.all_verified
    assert all(r.strategy == "ms2m_adaptive" for r in fleet.reports)
    assert "ms2m_adaptive" in fleet.row()["downtime_by_strategy"]


def test_adaptive_without_controller_synthesizes_cutoff(tmp_path):
    """Direct manager use, no CutoffController wired: the adaptive scheme
    must still be able to take the cutoff path from observed rates."""
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=3)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    broker.declare_queue("orders")
    stop = {"flag": False}

    def producer():
        rng = np.random.default_rng(0)
        while not stop["flag"]:
            yield float(rng.exponential(1.0 / 19.0))  # ~rho = 0.95
            broker.publish("orders", {"token": int(rng.integers(0, 99))})

    sim.process(producer())
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", HashConsumer(),
                                        broker.queues["orders"])
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    sim.run(until=10.0)

    mgr = MigrationManager(api, HashConsumer, "orders",
                           policy=MigrationPolicy(t_replay_max=20.0))
    done = mgr.migrate("ms2m_adaptive", holder["pod"], "node1")
    sim.run(stop_when=done)
    stop["flag"] = True
    report, target = done.value
    choice = next(e for e in report.events if e.kind == "adaptive_choice")
    assert choice.data["chosen"] == "ms2m_cutoff"
    assert report.t_end > report.t_start and not target.deleted


# ---------------------------------------------------------------------------
# Per-spec policy override in the orchestrator
# ---------------------------------------------------------------------------

def test_fleet_spec_policy_overrides_fleet_policy(tmp_path):
    from repro.core import ClusterMigrationOrchestrator, PodMigrationSpec

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=3)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    stop = {"flag": False}
    pods = {}
    for i in range(2):
        qname = f"orders-{i}"
        broker.declare_queue(qname)

        def producer(qname=qname):
            while not stop["flag"]:
                yield 0.125
                broker.publish(qname, {"token": 7})

        sim.process(producer())

        def boot(i=i, qname=qname):
            pod = yield from api.create_pod(f"c{i}", "node0", HashConsumer(),
                                            broker.queues[qname])
            pod.start()
            pods[i] = pod

        sim.process(boot())
    sim.run(until=8.0)

    orch = ClusterMigrationOrchestrator(api, HashConsumer)  # default policy
    specs = [
        PodMigrationSpec(pod=pods[0], queue="orders-0", target_node="node2"),
        PodMigrationSpec(pod=pods[1], queue="orders-1", target_node="node2",
                         policy=MigrationPolicy(precopy=True)),
    ]
    done = orch.migrate_fleet(specs)
    sim.run(stop_when=done)
    stop["flag"] = True
    fleet = done.value
    by_queue = {t.queue.name: r for r, t in zip(fleet.reports, fleet.targets)}
    assert by_queue["orders-0"].precopy_rounds == 0
    assert by_queue["orders-1"].precopy_rounds >= 1
