"""Contended network layer: fair-share Link math, topology routing,
flat-preset backward compatibility, mid-flight aborts and topology-aware
placement."""
import numpy as np
import pytest

from repro.cluster.network import (
    LinkSpec,
    NetworkTopology,
    available_topologies,
    edge_wan_topology,
    flat_topology,
    make_topology,
    topology_entries,
    two_zone_topology,
)
from repro.cluster.sim import Sim, TransferAborted
from repro.core import HashConsumer


# ---------------------------------------------------------------------------
# Link: fair-share flow math
# ---------------------------------------------------------------------------

def test_two_flows_split_bandwidth_then_survivor_speeds_up():
    """100B and 50B flows on a 10 B/s link, both starting at t=0: each
    runs at 5 B/s until the short one finishes at t=10; the survivor then
    gets the full 10 B/s and finishes its remaining 50B at t=15."""
    sim = Sim()
    link = sim.link(10.0)
    done = {}

    def flow(name, nbytes):
        yield from link.transfer(nbytes)
        done[name] = sim.now

    sim.process(flow("long", 100))
    sim.process(flow("short", 50))
    sim.run()
    assert done["short"] == pytest.approx(10.0)
    assert done["long"] == pytest.approx(15.0)  # work conserving: 150B/10Bps
    assert link.peak_flows == 2
    assert link.total_bytes == 150


def test_staggered_arrival_recomputes_rates():
    """A 100B flow alone for 5s (50B done), then a 25B flow joins: both
    run at 5 B/s, the newcomer finishes its 25B at t=10, and the first
    flow's last 25B run at full rate again -> t=12.5 (= 125B / 10 B/s,
    work conserving)."""
    sim = Sim()
    link = sim.link(10.0)
    done = {}

    def flow(name, nbytes, start):
        yield start
        yield from link.transfer(nbytes)
        done[name] = sim.now

    sim.process(flow("a", 100, 0.0))
    sim.process(flow("b", 25, 5.0))
    sim.run()
    assert done["b"] == pytest.approx(10.0)
    assert done["a"] == pytest.approx(12.5)


def test_unshared_link_has_no_contention():
    sim = Sim()
    link = sim.link(10.0, shared=False)
    done = {}

    def flow(name):
        yield from link.transfer(100)
        done[name] = sim.now

    sim.process(flow("a"))
    sim.process(flow("b"))
    sim.run()
    assert done == {"a": pytest.approx(10.0), "b": pytest.approx(10.0)}


def test_latency_charged_per_transfer_and_zero_bytes():
    sim = Sim()
    link = sim.link(10.0, latency_s=2.0)
    done = {}

    def flow(name, nbytes):
        yield from link.transfer(nbytes)
        done[name] = sim.now

    sim.process(flow("empty", 0))
    sim.process(flow("ten", 10))
    sim.run()
    assert done["empty"] == pytest.approx(2.0)   # latency only
    assert done["ten"] == pytest.approx(3.0)     # 2s latency + 1s wire


def test_abort_withdraws_flow_and_survivor_speeds_up():
    sim = Sim()
    link = sim.link(10.0)
    abort = sim.condition()
    out = {}

    def victim():
        try:
            yield from link.transfer(100, abort=abort)
        except TransferAborted:
            out["victim"] = ("aborted", sim.now)

    def survivor():
        yield from link.transfer(100)
        out["survivor"] = sim.now

    sim.process(victim())
    sim.process(survivor())
    sim.call_at(4.0, abort.trigger)
    sim.run()
    # survivor: 20B done by t=4 at 5 B/s, remaining 80B at 10 B/s -> t=12
    assert out["victim"] == ("aborted", 4.0)
    assert out["survivor"] == pytest.approx(12.0)
    assert link.aborted_flows == 1 and link.n_flows == 0
    # total_bytes counts DELIVERED traffic: survivor's 100B plus the 20B
    # the victim moved before the abort
    assert link.total_bytes == pytest.approx(120.0)


def test_abort_on_dedicated_link_mid_flight():
    """shared=False links honour the abort condition too (the docstring's
    contract), crediting only the bytes delivered before the abort."""
    sim = Sim()
    link = sim.link(10.0, shared=False)
    abort = sim.condition()
    out = {}

    def flow():
        try:
            yield from link.transfer(100, abort=abort)
            out["ok"] = True
        except TransferAborted:
            out["aborted"] = sim.now

    sim.process(flow())
    sim.call_at(4.0, abort.trigger)
    sim.run()
    assert out == {"aborted": 4.0}
    assert link.total_bytes == pytest.approx(40.0)  # 4s at 10 B/s delivered
    assert link.aborted_flows == 1


# ---------------------------------------------------------------------------
# Topology: classification, routing, presets
# ---------------------------------------------------------------------------

def test_link_classes_and_distance():
    topo = NetworkTopology(
        "t", {"n0": "a", "n1": "a", "n2": "b", "n3": "c"}, "a",
        {"intra": LinkSpec(100.0), "cross": LinkSpec(25.0),
         "wan": LinkSpec(5.0)},
        wan_pairs=[("a", "c")])
    assert topo.link_class("a", "a") == "intra"
    assert topo.link_class("a", "b") == "cross"
    assert topo.link_class("a", "c") == "wan"
    assert (topo.zone_distance("a", "a"), topo.zone_distance("a", "b"),
            topo.zone_distance("a", "c")) == (0, 1, 2)
    assert topo.registry_capacity_Bps("n1") == 100.0
    assert topo.registry_capacity_Bps("n2") == 25.0
    assert topo.registry_capacity_Bps("n3") == 5.0


def test_zone_pair_shares_one_link():
    topo = two_zone_topology(["n0", "n1", "n2", "n3"]).bind(Sim())
    assert topo.zone("n0") == "zone-a" and topo.zone("n3") == "zone-b"
    assert topo.registry_link("n2") is topo.registry_link("n3")
    assert topo.registry_link("n0") is not topo.registry_link("n2")


def test_make_topology_resolution_and_errors():
    assert available_topologies() == ["edge_wan", "flat", "two_zone"]
    assert {r["name"] for r in topology_entries()} == set(
        available_topologies())
    topo = make_topology("edge_wan", ["n0", "n1"], 100e6)
    assert topo.name == "edge_wan"
    assert make_topology(None, ["n0"], 1e6).name == "flat"
    assert make_topology(topo, [], 1e6) is topo
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology("nope", [], 1e6)
    with pytest.raises(TypeError):
        make_topology(42, [], 1e6)


def test_topology_binds_to_one_sim_only():
    topo = flat_topology(["n0"])
    sim = Sim()
    topo.bind(sim)
    topo.bind(sim)  # idempotent
    with pytest.raises(RuntimeError, match="already bound"):
        topo.bind(Sim())


def test_cross_zone_pull_charges_the_wan_link(tmp_path):
    """A pull to an edge node must put its bytes on the WAN link, not the
    core fabric; a core-node pull must not touch the WAN."""
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=4,
                      topology="edge_wan")
    sim, api = cluster.sim, cluster.api
    push = cluster.registry.push_image(
        {"state": {"blob": np.arange(4096, dtype=np.float32)}},
        meta={"last_msg_id": 0})
    wan = cluster.topology.link_between("core", "edge")
    core = cluster.topology.link_between("core", "core")

    done = sim.process(api.prefetch_image("node3", push.image_id))  # edge
    sim.run(stop_when=done)
    assert wan.total_bytes > 0
    wan_after_edge = wan.total_bytes
    assert core.total_bytes == 0

    done = sim.process(api.prefetch_image("node0", push.image_id))  # core
    sim.run(stop_when=done)
    assert core.total_bytes > 0
    assert wan.total_bytes == wan_after_edge
    # edge pull paid the WAN latency; its elapsed time reflects the spec
    assert cluster.topology.link_specs["wan"].latency_s > 0


# ---------------------------------------------------------------------------
# flat preset: bit-for-bit backward compatibility
# ---------------------------------------------------------------------------

def test_flat_preset_reproduces_seed_numbers_bit_for_bit(tmp_path):
    """The flat (default) topology must reproduce the pre-topology
    single-registry-link timeline exactly — values below were captured on
    the seed HEAD before the network layer existed."""
    from repro.core import run_migration_experiment

    r = run_migration_experiment("ms2m_cutoff", 8.0,
                                 registry_root=str(tmp_path / "reg"), seed=0)
    assert r.verified
    assert r.downtime == 1.4000000000000057
    assert r.migration_time == 75.00000024133189
    assert r.report.phases["image_build_push"] == 17.000000121333336
    assert r.report.phases["service_restoration"] == 21.000000120000003


def test_flat_preset_fleet_numbers_bit_for_bit(tmp_path):
    from repro.core import run_fleet_experiment

    fleet = run_fleet_experiment(
        4, "ms2m_precopy", 8.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", max_concurrent=4, seed=1)
    assert fleet.all_verified
    assert fleet.span == 143.25000096533816
    assert fleet.max_downtime == 1.4000000000000057
    assert fleet.total_downtime == 5.600000000000023
    (link,) = fleet.network["links"]
    assert link["shared"] is False  # flat = dedicated capacity


# ---------------------------------------------------------------------------
# Mid-flight aborts + orchestrator isolation
# ---------------------------------------------------------------------------

def _slow_shared_topology(node_names, registry_bw_Bps):
    return NetworkTopology("slow", {n: "rack" for n in node_names}, "rack",
                           {"intra": LinkSpec(1e5)})


def test_node_death_aborts_inflight_prefetch(tmp_path):
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2,
                      topology=_slow_shared_topology)
    sim, api = cluster.sim, cluster.api
    blob = np.random.default_rng(0).random(1 << 17).astype(np.float32)
    push = cluster.registry.push_image({"state": {"blob": blob}},
                                       meta={"last_msg_id": 0})
    caught = {}

    def prefetch():
        try:
            yield from api.prefetch_image("node1", push.image_id)
            caught["ok"] = True
        except TransferAborted as exc:
            caught["aborted"] = (sim.now, str(exc))

    # pull_base_s (5s) is charged first; the ~512KB flow then runs at
    # 100KB/s from t=5 to ~t=10.2 — kill at t=7, mid-flight
    sim.process(prefetch())
    sim.call_at(7.0, lambda: api.kill_node("node1"))
    sim.run()
    assert "ok" not in caught
    t_abort, msg = caught["aborted"]
    assert t_abort == pytest.approx(7.0)
    assert cluster.topology.registry_link("node1").aborted_flows == 1
    # nothing landed in the dead node's layer cache
    assert api.nodes["node1"].image_chunks == set()


def test_revive_rearms_the_abort_condition(tmp_path):
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2,
                      topology=_slow_shared_topology)
    sim, api = cluster.sim, cluster.api
    api.kill_node("node1")
    api.revive_node("node1")
    blob = np.random.default_rng(1).random(1 << 15).astype(np.float32)
    push = cluster.registry.push_image({"state": {"blob": blob}},
                                       meta={"last_msg_id": 0})
    done = sim.process(api.prefetch_image("node1", push.image_id))
    sim.run(stop_when=done)
    assert api.nodes["node1"].image_chunks  # transfer completed normally


def test_dead_node_transfer_fails_spec_not_fleet(tmp_path):
    """A target node killed mid-fleet fails that spec (TransferAborted or
    dead-node validation), never the fleet."""
    from repro.cluster.cluster import Cluster
    from repro.core import ClusterMigrationOrchestrator, PodMigrationSpec

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=3,
                      topology=_slow_shared_topology)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    stop = {"flag": False}
    pods = {}
    for i in range(2):
        qname = f"orders-{i}"
        broker.declare_queue(qname)

        def producer(i=i, qname=qname):
            while not stop["flag"]:
                yield 0.2
                broker.publish(qname, {"token": (i * 131) % 997})

        sim.process(producer())

        def boot(i=i, qname=qname):
            pod = yield from api.create_pod(
                f"consumer-{i}", "node0", HashConsumer(),
                broker.queues[qname])
            pod.start()
            pods[i] = pod

        sim.process(boot())
    sim.run(until=5.0)

    orch = ClusterMigrationOrchestrator(api, HashConsumer, max_concurrent=2)
    specs = [
        PodMigrationSpec(pod=pods[0], queue="orders-0", target_node="node1"),
        PodMigrationSpec(pod=pods[1], queue="orders-1", target_node="node2"),
    ]
    done = orch.migrate_fleet(specs)
    sim.call_at(sim.now + 4.0, lambda: api.kill_node("node2"))
    sim.run(stop_when=done)
    fleet = done.value
    stop["flag"] = True
    assert fleet.n_migrated == 1 and fleet.n_failed == 1
    assert fleet.failures[0]["target_node"] == "node2"
    assert fleet.reports[0].strategy == "ms2m_individual"


# ---------------------------------------------------------------------------
# Topology-aware placement
# ---------------------------------------------------------------------------

def _boot_pods(cluster, n, node="node0"):
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    stop = {"flag": False}
    pods = {}
    for i in range(n):
        qname = f"orders-{i}"
        broker.declare_queue(qname)

        def producer(i=i, qname=qname):
            while not stop["flag"]:
                yield 0.2
                broker.publish(qname, {"token": (i * 131) % 997})

        sim.process(producer())

        def boot(i=i, qname=qname):
            pod = yield from api.create_pod(
                f"consumer-{i}", node, HashConsumer(), broker.queues[qname])
            pod.start()
            pods[i] = pod

        sim.process(boot())
    sim.run(until=6.0)
    return pods, stop


def test_topology_placement_prefers_same_zone(tmp_path):
    """Draining a zone-a node in a two_zone cluster must keep the pods in
    zone-a (zero zone distance to both source and registry) instead of
    round-robining half of them across the thin cross-zone trunk."""
    from repro.cluster.cluster import Cluster
    from repro.core import ClusterMigrationOrchestrator

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=4,
                      topology="two_zone")
    sim, api = cluster.sim, cluster.api
    pods, stop = _boot_pods(cluster, 3)  # all on node0 (zone-a)

    orch = ClusterMigrationOrchestrator(api, HashConsumer)  # default policy
    done = orch.drain_node("node0")
    sim.run(stop_when=done)
    fleet = done.value
    stop["flag"] = True
    assert fleet.n_migrated == 3 and fleet.n_failed == 0
    assert all(t.node.name == "node1" for t in fleet.targets)  # zone-a


def test_round_robin_placement_still_available(tmp_path):
    from repro.cluster.cluster import Cluster
    from repro.core import ClusterMigrationOrchestrator

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=4,
                      topology="two_zone")
    sim, api = cluster.sim, cluster.api
    pods, stop = _boot_pods(cluster, 3)

    orch = ClusterMigrationOrchestrator(api, HashConsumer,
                                        placement="round_robin")
    done = orch.drain_node("node0")
    sim.run(stop_when=done)
    fleet = done.value
    stop["flag"] = True
    assert fleet.n_migrated == 3
    # blind rotation spreads across zones, including zone-b nodes
    assert {t.node.name for t in fleet.targets} == {"node1", "node2",
                                                    "node3"}


def test_topology_placement_balances_simultaneous_specs(tmp_path):
    """In a flat topology every candidate ties on distance and link load;
    the in-flight-target count must spread simultaneous placements
    instead of piling every pod onto the first node by name."""
    from repro.cluster.cluster import Cluster
    from repro.core import ClusterMigrationOrchestrator

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=4)  # flat
    sim, api = cluster.sim, cluster.api
    pods, stop = _boot_pods(cluster, 3)

    orch = ClusterMigrationOrchestrator(api, HashConsumer, max_concurrent=3)
    done = orch.drain_node("node0")
    sim.run(stop_when=done)
    fleet = done.value
    stop["flag"] = True
    assert fleet.n_migrated == 3
    assert {t.node.name for t in fleet.targets} == {"node1", "node2",
                                                    "node3"}


def test_unknown_placement_rejected(tmp_path):
    from repro.cluster.cluster import Cluster
    from repro.core import ClusterMigrationOrchestrator

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    with pytest.raises(ValueError, match="unknown placement"):
        ClusterMigrationOrchestrator(cluster.api, HashConsumer,
                                     placement="nope")


def test_auto_targets_resolved_by_placement(tmp_path):
    """Specs with target_node=None are placed by the policy at start
    time (and never onto the source's own node)."""
    from repro.core import run_fleet_experiment

    fleet = run_fleet_experiment(
        3, "ms2m_individual", 8.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", max_concurrent=3, seed=0, num_nodes=4,
        topology="two_zone", auto_targets=True)
    assert fleet.n_migrated == 3 and fleet.all_verified
    # sources: consumer-i on node{i}; a target may land anywhere except
    # its own source node
    for target in fleet.targets:
        src_idx = int(target.name.split("-")[1])
        assert target.node.name != f"node{src_idx}"
        # two_zone keeps zone-a sources in zone-a (nodes 0/1)
        if src_idx in (0, 1):
            assert target.node.name in ("node0", "node1")


# ---------------------------------------------------------------------------
# Contended fleet behaviour (the sweep's bend, in miniature)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_span_bends_upward_beyond_link_saturation(tmp_path):
    """On a shared link, pre-copy fleet span must be strictly worse at
    max_concurrent=6 than at 2: beyond saturation the contended rounds
    stop converging and ship strictly more wire bytes."""
    from benchmarks.fleet_migration import (_contended_timings,
                                            _shared_rack,
                                            churn_blob_factory)
    from repro.core import MigrationPolicy, run_fleet_experiment

    spans, wires = {}, {}
    for conc in (2, 6):
        fleet = run_fleet_experiment(
            6, "ms2m_precopy", 10.0,
            registry_root=str(tmp_path / f"reg{conc}"), mode="parallel",
            max_concurrent=conc, seed=0, num_nodes=4,
            timings=_contended_timings(1e6),
            worker_factory=churn_blob_factory, chunk_bytes=16 * 1024,
            topology=_shared_rack,
            policy=MigrationPolicy(precopy_max_rounds=8,
                                   precopy_converge_ratio=2.0,
                                   precopy_min_dirty=4))
        assert fleet.all_verified
        spans[conc] = fleet.span
        wires[conc] = fleet.wire_bytes_total
    assert spans[6] > spans[2]
    assert wires[6] > wires[2]


# ---------------------------------------------------------------------------
# ensure_node: explicit zones on multi-zone topologies
# ---------------------------------------------------------------------------

def test_ensure_node_autofiles_only_on_single_zone_topology():
    topo = flat_topology()
    topo.ensure_node("late-node")          # one zone: exactly one answer
    assert topo.zone("late-node") == topo.registry_zone
    topo.ensure_node("late-node")          # idempotent
    topo.ensure_node("late-node", zone=topo.registry_zone)  # consistent


def test_ensure_node_requires_zone_when_multizone():
    """Silently filing an unknown node next to the registry gives it
    zone_distance == 0 and biases every placement score toward it."""
    topo = two_zone_topology(["n0", "n1"])
    assert topo.is_multizone()
    with pytest.raises(ValueError, match="explicit zone"):
        topo.ensure_node("mystery-node")
    assert "mystery-node" not in topo.zone_of  # nothing half-registered
    topo.ensure_node("mystery-node", zone="zone-b")
    assert topo.zone("mystery-node") == "zone-b"


def test_ensure_node_rejects_conflicting_reregistration():
    topo = two_zone_topology(["n0", "n1"])
    topo.ensure_node("n-edge", zone="zone-b")
    with pytest.raises(ValueError, match="already in zone"):
        topo.ensure_node("n-edge", zone="zone-a")
    assert topo.zone("n-edge") == "zone-b"  # registration untouched


def test_cluster_add_node_does_not_half_add_on_zone_error(tmp_path):
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2,
                      topology="two_zone")
    with pytest.raises(ValueError):
        cluster.api.add_node("node-late")   # multi-zone: zone required
    assert "node-late" not in cluster.api.nodes
    assert "node-late" not in cluster.api.topology.zone_of
    node = cluster.api.add_node("node-late", zone="zone-b")
    assert node.name in cluster.api.nodes
    assert cluster.api.topology.zone("node-late") == "zone-b"
