"""Per-kernel correctness: Pallas (interpret mode) and blockwise-jnp
formulations vs the naive oracles, swept over shapes/dtypes/masks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as pl_decode
from repro.kernels.flash_attention import flash_attention as pl_flash
from repro.kernels.rglru import rglru as pl_rglru

TOL = dict(rtol=2e-2, atol=2e-3)  # bf16-friendly
TOL32 = dict(rtol=1e-4, atol=1e-5)


def _qkv(key, B, S, H, Hkv, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 4, 4, 64),    # MHA
    (2, 256, 8, 2, 64),    # GQA 4:1
    (1, 256, 4, 1, 128),   # MQA
    (2, 128, 6, 3, 32),    # odd ratios
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_kernel(B, S, H, Hkv, D, dtype, window):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, Hkv, D, dtype)
    want = ref.naive_attention(q, k, v, causal=True, window=window)
    got = pl_flash(q, k, v, causal=True, window=window,
                   block_q=64, block_k=64, interpret=True)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("block_k", [32, 128, 1024])
def test_blockwise_attention_matches_naive(block_k):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 192, 4, 2, 64, jnp.float32)
    want = ref.naive_attention(q, k, v, causal=True)
    got = ref.blockwise_attention(q, k, v, causal=True, block_k=block_k)
    np.testing.assert_allclose(got, want, **TOL32)


def test_banded_local_attention_matches_naive():
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 256, 4, 2, 64, jnp.float32)
    want = ref.naive_attention(q, k, v, causal=True, window=64)
    got = ref.banded_local_attention(q, k, v, window=64)
    np.testing.assert_allclose(got, want, **TOL32)


@pytest.mark.parametrize("B,S,H,Hkv,D", [(2, 256, 8, 2, 64), (1, 128, 4, 4, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_kernel(B, S, H, Hkv, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    qpos = jnp.array([S // 2, S - 1][:B])
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kpos = jnp.where(kpos <= qpos[:, None], kpos, -1)
    want = ref.decode_attention(q, kc, vc, q_pos=qpos, k_pos=kpos)
    got = pl_decode(q, kc, vc, qpos, kpos, block_k=64, interpret=True)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("B,S,W", [(1, 64, 128), (2, 128, 256), (1, 96, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_kernel(B, S, W, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (B, S, W), dtype)
    ga = jax.random.normal(ks[1], (B, S, W), dtype)
    gx = jax.random.normal(ks[2], (B, S, W), dtype)
    a = jax.random.normal(ks[3], (W,), jnp.float32)
    want_seq, want_last = ref.naive_rglru(x, a, ga, gx)
    chunk = 32
    got_seq, got_last = pl_rglru(x, a, ga, gx, block_w=128, chunk=chunk,
                                 interpret=True)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(got_seq, np.float32),
                               np.asarray(want_seq, np.float32), **tol)
    np.testing.assert_allclose(got_last, want_last, **TOL32)


def test_rglru_blockwise_matches_naive():
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    B, S, W = 2, 160, 48
    x = jax.random.normal(ks[0], (B, S, W))
    ga = jax.random.normal(ks[1], (B, S, W))
    gx = jax.random.normal(ks[2], (B, S, W))
    a = jax.random.normal(ks[3], (W,))
    want_seq, want_last = ref.naive_rglru(x, a, ga, gx)
    got_seq, got_last = ref.blockwise_rglru(x, a, ga, gx, block=32)
    np.testing.assert_allclose(got_seq, want_seq, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got_last, want_last, rtol=1e-3, atol=1e-4)


def test_rglru_state_carry():
    """Kernel with h0 continues exactly from a previous chunk."""
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    B, S, W = 1, 128, 128
    x = jax.random.normal(ks[0], (B, S, W))
    ga = jax.random.normal(ks[1], (B, S, W))
    gx = jax.random.normal(ks[2], (B, S, W))
    a = jax.random.normal(ks[3], (W,))
    full_seq, full_last = ref.naive_rglru(x, a, ga, gx)
    h_mid = ref.naive_rglru(x[:, :64], a, ga[:, :64], gx[:, :64])[1]
    got_seq, got_last = pl_rglru(x[:, 64:], a, ga[:, 64:], gx[:, 64:],
                                 h_mid, block_w=128, chunk=32, interpret=True)
    np.testing.assert_allclose(got_last, full_last, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("B,S,H,D", [(1, 64, 2, 32), (2, 128, 4, 64)])
@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunkwise_kernel(B, S, H, D, chunk, dtype):
    from repro.kernels.mlstm import mlstm as pl_mlstm
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, H, D), dtype)
    v = jax.random.normal(ks[2], (B, S, H, D), dtype)
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    want, _ = ref.naive_mlstm(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), ig, fg)
    got = pl_mlstm(q, k, v, ig, fg, chunk=chunk, interpret=True)
    tol = dict(rtol=5e-2, atol=0.3) if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("B,S,H,hb", [(1, 32, 2, 16), (2, 64, 4, 32)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_slstm_kernel(B, S, H, hb, chunk):
    from repro.kernels.slstm import slstm as pl_slstm
    W = H * hb
    ks = jax.random.split(jax.random.PRNGKey(10), 8)
    xi, xf, xz, xo = (jax.random.normal(k, (B, S, W)) for k in ks[:4])
    ri, rf, rz, ro = (jax.random.normal(k, (H, hb, hb)) * 0.2
                      for k in ks[4:])
    want, _ = ref.naive_slstm(xi, xf, xz, xo, ri, rf, rz, ro)
    got = pl_slstm(xi, xf, xz, xo, ri, rf, rz, ro, chunk=chunk,
                   interpret=True)
    np.testing.assert_allclose(got, want, **TOL32)


def test_mlstm_scan_vs_decode_consistency():
    B, S, H, D = 2, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    hs, state = ref.naive_mlstm(q, k, v, ig, fg)
    st = (jnp.zeros((B, H, D, D)), jnp.zeros((B, H, D)),
          jnp.full((B, H), ref.NEG_INF))
    outs = []
    for t in range(S):
        st, h = ref.mlstm_decode_step(st, q[:, t], k[:, t], v[:, t],
                                      ig[:, t], fg[:, t])
        outs.append(h)
    np.testing.assert_allclose(jnp.stack(outs, 1), hs, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(st[0], state[0], rtol=1e-4, atol=1e-5)


def test_chunk_attention_matches_decode_fold():
    """lm_append's attention primitive == sequential decode attention."""
    B, S, H, Hkv, D = 1, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    S_cache = 128
    kc = jnp.zeros((B, S_cache, Hkv, D))
    vc = jnp.zeros((B, S_cache, Hkv, D))
    kpos = jnp.full((B, S_cache), -1, jnp.int32)
    knew = jax.random.normal(ks[0], (B, S, Hkv, D))
    vnew = jax.random.normal(ks[1], (B, S, Hkv, D))
    q = jax.random.normal(ks[2], (B, S, H, D))
    # populate cache with the chunk
    kc = kc.at[:, :S].set(knew)
    vc = vc.at[:, :S].set(vnew)
    kpos = kpos.at[:, :S].set(jnp.arange(S)[None])
    qpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    got = ref.chunk_attention(q, kc, vc, q_pos=qpos, k_pos=kpos)
    # reference: causal attention over the chunk
    want = ref.naive_attention(q, knew, vnew, causal=True)
    np.testing.assert_allclose(got, want, **TOL32)
