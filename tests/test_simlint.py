"""Fixture coverage for every simlint rule (SIM001-SIM005), the
suppression pragma, and the clean-tree gate on src/repro itself."""
import os
import textwrap

from repro.analysis.lint import RULES, Finding, lint_paths, lint_source

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def rules_of(src):
    return [f.rule for f in lint_source(textwrap.dedent(src))]


# -- SIM001: broad except swallowing Interrupt in a generator ----------------
def test_sim001_flags_broad_except_in_generator():
    assert rules_of("""
        def proc(ctx):
            try:
                yield 1.0
            except Exception:
                pass
    """) == ["SIM001"]


def test_sim001_bare_except_also_flagged():
    assert rules_of("""
        def proc(ctx):
            try:
                yield 1.0
            except:
                pass
    """) == ["SIM001"]


def test_sim001_passes_with_prior_interrupt_handler():
    assert rules_of("""
        def proc(ctx):
            try:
                yield 1.0
            except Interrupt:
                raise
            except Exception:
                pass
    """) == []


def test_sim001_passes_when_handler_just_reraises():
    assert rules_of("""
        def proc(ctx):
            try:
                yield 1.0
            except Exception:
                raise
    """) == []


def test_sim001_ignores_non_generators():
    assert rules_of("""
        def helper():
            try:
                return 1
            except Exception:
                return None
    """) == []


# -- SIM002: wall clock / unseeded randomness --------------------------------
def test_sim002_flags_wall_clock_and_global_rng():
    src = """
        import random
        import time

        def sample():
            t = time.time()
            r = random.random()
            n = np.random.randint(10)
            return t, r, n
    """
    assert rules_of(src) == ["SIM002", "SIM002", "SIM002"]


def test_sim002_seeded_randomness_is_legal():
    assert rules_of("""
        def sample(seed, key):
            rng = np.random.default_rng(seed)
            ks = jax.random.split(key, 3)
            t0 = time.perf_counter()  # measures real compute, not schedule
            return rng.integers(0, 10), ks, t0
    """) == []


def test_sim002_suppression_pragma():
    assert rules_of("""
        def compile_timer():
            t0 = time.time()  # simlint: disable=SIM002
            # the pragma also works on the line above:
            # simlint: disable=SIM002
            t1 = time.time()
            return t1 - t0
    """) == []


def test_suppression_does_not_leak_to_other_rules():
    assert rules_of("""
        def sample():
            return time.time()  # simlint: disable=SIM001
    """) == ["SIM002"]


# -- SIM003: ordering-sensitive iteration ------------------------------------
def test_sim003_flags_set_iteration():
    assert rules_of("""
        def schedule(jobs):
            for j in set(jobs):
                launch(j)
    """) == ["SIM003"]


def test_sim003_flags_anyof_over_live_dict_view():
    assert rules_of("""
        def drive(sim, active):
            yield sim.any_of(*active.keys())
    """) == ["SIM003"]


def test_sim003_flags_mutation_during_iteration():
    assert rules_of("""
        def drain(active):
            for cond in active:
                active.pop(cond)
    """) == ["SIM003"]


def test_sim003_sorted_and_snapshotted_are_legal():
    assert rules_of("""
        def drive(sim, active):
            armed = list(active.keys())
            yield sim.any_of(*armed)
            for cond in sorted(active):
                done(cond)
    """) == []


# -- SIM004: busy-poll loops --------------------------------------------------
def test_sim004_flags_busy_poll():
    assert rules_of("""
        def drain(queue):
            while queue.depth() > 0:
                yield 0.05
    """) == ["SIM004"]


def test_sim004_large_delays_and_conditions_are_legal():
    assert rules_of("""
        def heartbeat(sim, interval, wake):
            while True:
                yield 5.0
            while True:
                yield interval
            while True:
                yield wake
    """) == []


# -- SIM005: on_trigger in a loop without detach ------------------------------
def test_sim005_flags_undetached_loop_registration():
    assert rules_of("""
        def driver(conds, wake):
            while True:
                for c in conds:
                    c.on_trigger(print)
                yield wake
    """) == ["SIM005"]


def test_sim005_paired_detach_is_legal():
    assert rules_of("""
        def driver(conds, wake):
            while True:
                for c in conds:
                    c.on_trigger(print)
                yield wake
                for c in conds:
                    c.detach(print)
    """) == []


# -- harness ------------------------------------------------------------------
def test_finding_format_is_clickable():
    f = Finding("src/x.py", 12, 4, "SIM002", "msg")
    assert f.format() == "src/x.py:12:4: SIM002 msg"
    assert f.as_dict()["rule"] == "SIM002"


def test_all_five_rules_have_fixture_coverage():
    assert sorted(RULES) == ["SIM001", "SIM002", "SIM003", "SIM004",
                             "SIM005"]


def test_src_repro_tree_is_clean():
    """The CI gate: the live tree must lint clean (suppressions count as
    clean — they are the documented escape hatch)."""
    findings = lint_paths([os.path.join(REPO, "src", "repro")])
    assert findings == [], "\n".join(f.format() for f in findings)
