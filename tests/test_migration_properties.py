"""Hypothesis property tests over the system invariants.

Invariant 1 (the MS2M premise): for ANY strategy, rate, seed and timing
profile, the migrated worker's state equals the reference fold of the
message log — no loss, duplication, or reordering.

Invariant 2: downtime <= migration time, both positive.

Invariant 3 (Eq. 5): when the cutoff fires, accumulated-replay work stays
bounded near λ·T_cutoff/μ.
"""
import os

import pytest
pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import run_migration_experiment

STRATEGIES = ("stop_and_copy", "ms2m_individual", "ms2m_cutoff",
              "ms2m_statefulset")


@given(
    strategy=st.sampled_from(STRATEGIES),
    rate=st.floats(min_value=0.5, max_value=19.0),
    seed=st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_any_migration_preserves_state(tmp_path_factory, strategy, rate, seed):
    root = str(tmp_path_factory.mktemp("reg"))
    r = run_migration_experiment(strategy, rate, registry_root=root,
                                 seed=seed, settle_time=3.0)
    assert r.verified
    assert 0 < r.downtime <= r.migration_time + 1e-6


@given(
    rate=st.floats(min_value=12.0, max_value=19.5),
    t_replay_max=st.floats(min_value=5.0, max_value=30.0),
    seed=st.integers(min_value=0, max_value=100),
)
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_cutoff_replay_bound_property(tmp_path_factory, rate, t_replay_max,
                                      seed):
    root = str(tmp_path_factory.mktemp("reg"))
    r = run_migration_experiment("ms2m_cutoff", rate, registry_root=root,
                                 seed=seed, t_replay_max=t_replay_max)
    assert r.verified
    if r.report.cutoff_fired:
        # replayed messages accumulated over <= T_cutoff + transfer window;
        # the bounded drain itself respects ~T_replay_max at service rate mu
        mu = r.mu
        drain_after_pause = r.report.phases.get("cutover", 0.0)
        assert drain_after_pause <= t_replay_max + 10.0


@given(seed=st.integers(min_value=0, max_value=1000))
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_deterministic_virtual_time(tmp_path_factory, seed):
    """Same seed -> bit-identical timings (the sim is deterministic)."""
    r1 = run_migration_experiment(
        "ms2m_individual", 8.0,
        registry_root=str(tmp_path_factory.mktemp("a")), seed=seed)
    r2 = run_migration_experiment(
        "ms2m_individual", 8.0,
        registry_root=str(tmp_path_factory.mktemp("b")), seed=seed)
    assert r1.migration_time == r2.migration_time
    assert r1.downtime == r2.downtime
    assert r1.report.phases == r2.report.phases
