"""One real dry-run cell in a subprocess (512 virtual devices need a fresh
jax), proving the launch path end-to-end inside the test suite."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    out = tmp_path / "cell.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "smollm_360m", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    row = json.loads(out.read_text().splitlines()[0])
    assert row["status"] == "OK"
    assert row["chips"] == 256
    assert row["roofline"]["memory_s"] > 0
    assert row["collectives"]["total_bytes"] > 0


@pytest.mark.slow
def test_dryrun_skip_rule(tmp_path):
    """long_500k must SKIP for full-attention archs without compiling."""
    out = tmp_path / "skip.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "codeqwen1_5_7b", "--shape", "long_500k",
         "--mesh", "single", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=300,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert proc.returncode == 0
    row = json.loads(out.read_text().splitlines()[0])
    assert row["status"] == "SKIP"
    assert "sub-quadratic" in row["reason"]
