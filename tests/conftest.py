import os
import sys

# keep the smoke tests on 1 device (the dry-run sets its own device count)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# -- shared hypothesis strategies -------------------------------------------
# hypothesis is a dev-only dependency (requirements-dev.txt): the property
# suites guard with importorskip/skipif, and these strategies only exist
# when the library does.
try:
    from hypothesis import strategies as _st
except ImportError:
    _st = None

if _st is not None:
    import numpy as _np

    @_st.composite
    def codec_leaf_pairs(draw, max_elems=4096, dtype=_np.float32):
        """(cur, parent) same-shape leaves with a drawn dirt pattern —
        the input space of the delta codecs: clean (empty delta), thin
        dirty stripes (the RLE sweet spot), or fully redrawn
        (incompressible, exercising the raw-literal fallback).  Sizes
        deliberately straddle the 512-byte kernel word grid and chunk
        boundaries."""
        n = draw(_st.integers(min_value=1, max_value=max_elems))
        seed = draw(_st.integers(min_value=0, max_value=2**32 - 1))
        kind = draw(_st.sampled_from(["clean", "stripes", "dense"]))
        rng = _np.random.default_rng(seed)
        cur = rng.standard_normal(n).astype(dtype)
        if kind == "clean":
            parent = cur.copy()
        elif kind == "dense":
            parent = rng.standard_normal(n).astype(dtype)
        else:
            parent = cur.copy()
            stripes = draw(_st.integers(min_value=1, max_value=4))
            for _ in range(stripes):
                i = draw(_st.integers(min_value=0, max_value=n - 1))
                w = draw(_st.integers(min_value=1, max_value=64))
                parent[i: i + w] += 1.0
        return cur, parent

    @_st.composite
    def sparse_byte_vectors(draw, max_len=2048):
        """Mostly-zero uint8 vectors for the RLE layer itself, with runs
        and gaps drawn around the encoder's 16-byte gap-absorption
        threshold."""
        n = draw(_st.integers(min_value=1, max_value=max_len))
        x = _np.zeros(n, _np.uint8)
        for _ in range(draw(_st.integers(min_value=0, max_value=6))):
            i = draw(_st.integers(min_value=0, max_value=n - 1))
            w = draw(_st.integers(min_value=1, max_value=48))
            v = draw(_st.integers(min_value=1, max_value=255))
            x[i: i + w] = v
        return x
