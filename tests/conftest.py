import os
import sys

# keep the smoke tests on 1 device (the dry-run sets its own device count)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
